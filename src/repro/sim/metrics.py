"""Performance metrics of Section 6.1: acceptance rate and slowdown.

Includes the on-device grid reductions (:func:`grid_reductions`) and
the NaN-safe aggregation helpers: a grid cell that accepts zero jobs
has no slowdown (and an all-padding cell no utilization), so those
cells carry ``NaN`` and every :class:`GridResult` reduction masks them
instead of dividing by zero or tripping numpy's all-NaN warnings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def nanmean_safe(a) -> float:
    """Mean over finite entries; NaN (no warning) when none are."""
    a = np.asarray(a, dtype=float)
    m = np.isfinite(a)
    if not m.any():
        return float("nan")
    return float(a[m].mean())


@dataclasses.dataclass
class SimResult:
    """Outcome of one simulation run."""

    policy: str
    n_jobs: int
    n_accepted: int
    slowdowns: List[float] = dataclasses.field(default_factory=list)
    busy_area: float = 0.0          # accepted PE-seconds
    span: float = 0.0               # makespan of the arrival stream
    n_pe: int = 0
    wall_seconds: float = 0.0       # scheduler wall time (data-structure cost)
    # per-job (accepted, t_s) trace; populated on request only
    decisions: Optional[List[Tuple[bool, int]]] = None

    @property
    def acceptance_rate(self) -> float:
        return self.n_accepted / max(self.n_jobs, 1)

    @property
    def avg_slowdown(self) -> float:
        if not self.slowdowns:
            return float("nan")
        return sum(self.slowdowns) / len(self.slowdowns)

    @property
    def utilization(self) -> float:
        # a run with no makespan (or no machine) has no utilization:
        # NaN, so nanmean_safe-style aggregations mask it instead of
        # averaging in a silently wrong busy_area / n_pe ratio
        denom = self.n_pe * self.span
        if denom <= 0:
            return float("nan")
        return self.busy_area / denom

    def summary(self) -> str:
        return (f"{self.policy:8s} accept={self.acceptance_rate:.3f} "
                f"slowdown={self.avg_slowdown:.3f} "
                f"util={self.utilization:.3f} "
                f"sched_wall={self.wall_seconds:.2f}s")


@dataclasses.dataclass
class GridResult:
    """Stacked metrics of one vmapped Section-6 sweep grid.

    Every metric array is indexed ``[policy, backfill, load, seed,
    flexibility]`` — the cell order of
    :func:`repro.sim.sweep.simulate_grid`.  ``backfill_modes`` is the
    grid's deferral-mode axis (``("none",)`` for the classic paper
    matrix).  A cell that accepts no jobs carries ``NaN`` slowdown (an
    all-padding cell ``NaN`` utilization); the reductions below mask
    those cells.
    """

    policies: Tuple[str, ...]
    arrival_factors: Tuple[float, ...]
    seeds: Tuple[int, ...]
    flex_factors: Tuple[float, ...]
    backfill_modes: Tuple[str, ...]
    acceptance: np.ndarray        # float [P, B, L, S, F]
    slowdown: np.ndarray          # float [P, B, L, S, F] (nan: empty)
    utilization: np.ndarray       # float [P, B, L, S, F]
    n_jobs: np.ndarray            # int   [P, B, L, S, F] valid jobs
    n_accepted: np.ndarray        # int   [P, B, L, S, F]
    wall_seconds: float = 0.0     # one dispatch for the whole grid
    # per-cell (accepted, t_s) traces, populated on request only:
    # decisions[p][b][l][s][f] is a list over the cell's unpadded jobs
    decisions: Optional[list] = None

    @property
    def n_cells(self) -> int:
        return int(np.prod(self.acceptance.shape))

    @property
    def cells_per_sec(self) -> float:
        return self.n_cells / max(self.wall_seconds, 1e-9)

    def policy_acceptance(self) -> Dict[str, float]:
        """Grid-mean acceptance rate per policy (paper Figs. 2/4/6)."""
        return {p: nanmean_safe(self.acceptance[i])
                for i, p in enumerate(self.policies)}

    def policy_slowdown(self) -> Dict[str, float]:
        """Grid-mean slowdown per policy (paper Figs. 3/5/7).

        Empty cells (zero accepted jobs) are masked, not averaged.
        """
        return {p: nanmean_safe(self.slowdown[i])
                for i, p in enumerate(self.policies)}

    def mode_policy_acceptance(self) -> Dict[str, Dict[str, float]]:
        """Per backfill mode, grid-mean acceptance per policy."""
        return {m: {p: nanmean_safe(self.acceptance[i, b])
                    for i, p in enumerate(self.policies)}
                for b, m in enumerate(self.backfill_modes)}

    def mode_policy_slowdown(self) -> Dict[str, Dict[str, float]]:
        """Per backfill mode, grid-mean slowdown per policy."""
        return {m: {p: nanmean_safe(self.slowdown[i, b])
                    for i, p in enumerate(self.policies)}
                for b, m in enumerate(self.backfill_modes)}

    def summary(self) -> str:
        lines = [f"{self.n_cells} cells in {self.wall_seconds:.2f}s "
                 f"({self.cells_per_sec:.1f} cells/s)"]
        by_acc = self.mode_policy_acceptance()
        by_sd = self.mode_policy_slowdown()
        for m in self.backfill_modes:
            head = f" [{m}]" if len(self.backfill_modes) > 1 else ""
            for p in self.policies:
                lines.append(
                    f"  {p:8s}{head} accept={by_acc[m][p]:.3f} "
                    f"slowdown={by_sd[m][p]:.3f}")
        return "\n".join(lines)


def grid_reductions(dec, batch, valid: np.ndarray, n_pe: int):
    """Per-cell metric reductions, computed on-device, synced once.

    ``dec``/``batch`` are the stacked ``[C, N]`` decision/request
    arrays of one grid dispatch, ``valid`` the padding mask.  Returns
    host ``(n_accepted, n_valid, acceptance, slowdown, utilization)``
    arrays of shape ``[C]``.  NaN-safe: a cell with zero accepted jobs
    gets ``NaN`` slowdown, a cell with zero valid jobs ``NaN``
    utilization — downstream reductions mask them
    (:func:`nanmean_safe`) instead of dividing by zero.
    """
    import jax.numpy as jnp

    v = jnp.asarray(valid)
    acc = dec.accepted & v                             # [C, N]
    n_acc = jnp.sum(acc, axis=1)
    n_val = jnp.sum(v, axis=1)
    t_du = batch.t_du.astype(jnp.float32)
    wait = (dec.t_s - batch.t_r + batch.t_du).astype(jnp.float32)
    slow = jnp.where(acc, wait / jnp.maximum(t_du, 1), 0.0)
    slowdown = jnp.sum(slow, axis=1) / jnp.maximum(n_acc, 1)
    slowdown = jnp.where(n_acc > 0, slowdown, jnp.nan)
    # accumulate PE-seconds in f32: paper-scale cells (~1e11) overflow
    # an int32 sum, and utilization is a ratio so 1e-7 relative error
    # is immaterial
    area = jnp.sum(jnp.where(
        acc, (batch.n_pe * batch.t_du).astype(jnp.float32), 0.0),
        axis=1)
    t_a = jnp.where(v, batch.t_a, 0)
    first = jnp.min(jnp.where(v, batch.t_a, jnp.int32(2**31 - 1)),
                    axis=1)
    span = jnp.maximum(jnp.max(t_a, axis=1), 1) - first + 1
    util = area.astype(jnp.float32) / (n_pe * span.astype(jnp.float32))
    util = jnp.where(n_val > 0, util, jnp.nan)
    rate = n_acc / jnp.maximum(n_val, 1).astype(jnp.float32)
    return (np.asarray(n_acc), np.asarray(n_val), np.asarray(rate),
            np.asarray(slowdown), np.asarray(util))


def mean_ci95(values: Sequence[float]) -> tuple:
    """(mean, half-width of the normal-approx 95% CI)."""
    n = len(values)
    if n == 0:
        return float("nan"), float("nan")
    mean = sum(values) / n
    if n == 1:
        return mean, float("nan")
    var = sum((v - mean) ** 2 for v in values) / (n - 1)
    return mean, 1.96 * math.sqrt(var / n)
