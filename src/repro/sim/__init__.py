"""Discrete-event simulation of the paper's Section 6 experiments."""
from repro.sim.metrics import GridResult, SimResult, mean_ci95  # noqa: F401
from repro.sim.simulator import (  # noqa: F401
    run_policies,
    simulate,
    simulate_batched,
)
from repro.sim.sweep import GridSpec, pad_streams, simulate_grid  # noqa: F401
from repro.sim.workload import (  # noqa: F401
    WorkloadParams,
    generate,
    generate_filtered,
)
