"""Section-6 sweep grids as one vmapped device dispatch (DESIGN.md §4).

The paper evaluates its seven policies by sequentially simulating a
grid of job sizes, durations, loads and flexibilities.  With the
functional core's ensemble axis (:mod:`repro.core.ensemble`) that grid
— policies × backfill modes × loads × seeds × flexibilities — becomes
*lanes of one vmapped scan*: every cell's request stream is
materialised on the host (:mod:`repro.sim.workload`), padded to a
common fixed shape, stacked, and offered to one ensemble
:class:`repro.api.Session` (lanes = cells, one-shot mode) in a single
jitted dispatch.  The backfill mode is *traced* per lane (DESIGN.md
§6), so the 7 × {none, easy, conservative} matrix compiles once.  The
acceptance / slowdown / utilization metrics are reduced on-device and
returned stacked as a :class:`~repro.sim.metrics.GridResult`.

The host event loop (:func:`repro.sim.simulator.simulate`) remains the
oracle for ``backfill="none"`` cells, and the host backfilling oracle
(:class:`repro.core.hostsched.BackfillOracle`) for the others:
``cross_check=True`` asserts per-job decision identity for every cell.
"""
from __future__ import annotations

import dataclasses
import itertools
import time as _time
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.api import ReservationService, ServiceConfig
from repro.core.batch import pad_streams
from repro.core.policies import policy_index
from repro.core.types import ALL_POLICIES, Policy
from repro.sim.metrics import GridResult, grid_reductions
from repro.sim.workload import WorkloadParams, generate_filtered


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """The experiment matrix: policies × backfill × loads × seeds × flex.

    ``arrival_factors`` rescale arrivals (higher = heavier load, paper
    Figs. 4-5); ``flex_factors`` set both the AR-time and deadline
    factor (Figs. 6-7); ``backfill_modes`` adds the deferral-queue
    scenario axis (DESIGN.md §6) with ``park_capacity`` queue slots per
    lane.  ``tenant_mixes`` adds the multi-tenancy axis (DESIGN.md
    §10): each entry is a :class:`repro.tenancy.TenantSpec` (jobs are
    assigned tenants round-robin) or ``None`` for the single-tenant
    baseline; the default ``(None,)`` keeps the legacy 5-axis result
    shapes.  ``resources`` generalises the machine to a multi-resource
    layout (DESIGN.md §11; ``resources[0]`` must equal ``n_pe``) and
    ``resource_mixes`` adds the secondary-demand axis: each entry is a
    tuple of R-1 intensity fractions — job ``j`` gets
    ``demand[r] = min(units[r], round(f_r * units[r] * j.n_pe /
    n_pe))`` on plane ``r`` — or ``None`` for PE-only demand.
    ``base`` supplies every other workload knob.
    """

    policies: Tuple[Policy, ...] = ALL_POLICIES
    arrival_factors: Tuple[float, ...] = (0.75, 1.0, 1.25)
    seeds: Tuple[int, ...] = (0, 1, 2)
    flex_factors: Tuple[float, ...] = (3.0,)
    backfill_modes: Tuple[str, ...] = ("none",)
    tenant_mixes: Tuple[Optional[object], ...] = (None,)
    resources: Optional[Tuple[int, ...]] = None
    resource_mixes: Tuple[Optional[Tuple[float, ...]], ...] = (None,)
    base: WorkloadParams = WorkloadParams()
    n_pe: int = 64
    n_jobs: int = 200
    park_capacity: int = 8

    @property
    def rspec(self):
        """The grid's :class:`~repro.core.resources.ResourceSpec`."""
        if self.resources is None:
            return None
        from repro.core.resources import ResourceSpec
        return ResourceSpec(self.resources)

    @property
    def shape(self) -> Tuple[int, ...]:
        base = (len(self.policies), len(self.backfill_modes),
                len(self.arrival_factors), len(self.seeds),
                len(self.flex_factors))
        if len(self.tenant_mixes) > 1:
            base = base + (len(self.tenant_mixes),)
        if len(self.resource_mixes) > 1:
            base = base + (len(self.resource_mixes),)
        return base

    @property
    def n_cells(self) -> int:
        return int(np.prod(self.shape))

    def workload_params(self, load: float, seed: int,
                        flex: float) -> WorkloadParams:
        return self.base.replace(
            n_jobs=self.n_jobs, n_pe=self.n_pe, arrival_factor=load,
            seed=seed, artime_factor=flex, deadline_factor=flex)


def simulate_grid(
    spec: Optional[GridSpec] = None,
    *,
    capacity: int = 128,
    pending_capacity: int = 256,
    use_kernel: bool = False,
    cross_check: bool = False,
    record_decisions: bool = False,
    placement="auto",
    donate: bool = True,
    **overrides,
) -> GridResult:
    """Run the whole experiment matrix as one vmapped on-device scan.

    Each (load, seed, flexibility) workload is generated once and
    shared by all policies and backfill modes — the paper's setup.  All
    cells admit in lockstep via
    :func:`repro.core.ensemble.admit_stream_ensemble_auto` (one growth
    covers the worst lane; policy and backfill mode are traced per
    lane, so no cell recompiles), and the stacked metrics come back as
    a :class:`GridResult` indexed ``[policy, backfill, load, seed,
    flex]``.  ``cross_check=True`` re-runs every cell on the host
    oracle (event loop / :class:`~repro.core.hostsched.BackfillOracle`)
    and asserts per-job decision identity.

    ``placement`` shards the cell axis over the local devices
    (``ServiceConfig.placement``, DESIGN.md §8): on an N-device host
    each device scans ``cells/N`` lanes of the same single dispatch,
    with bit-identical decisions to ``placement="single"``.
    ``donate=False`` disables state-buffer donation (keeps the old
    allocation behaviour; decisions are unaffected either way).
    """
    spec = dataclasses.replace(spec or GridSpec(), **overrides)
    shape = spec.shape
    # one workload per (load, seed, flex), shared across policy/mode;
    # tenant mixes re-stamp the shared stream round-robin
    workloads = {}
    for load, seed, flex in itertools.product(
            spec.arrival_factors, spec.seeds, spec.flex_factors):
        jobs = generate_filtered(
            spec.workload_params(load, seed, flex), max_pe=spec.n_pe)
        workloads[(load, seed, flex)] = sorted(
            jobs, key=lambda j: j.t_a)
    mixes = spec.tenant_mixes
    tenanted = {}
    for key, jobs in workloads.items():
        for m, mix in enumerate(mixes):
            if mix is None:
                tenanted[key + (m,)] = jobs
            else:
                T = mix.n_tenants
                tenanted[key + (m,)] = [
                    dataclasses.replace(j, tenant=i % T)
                    for i, j in enumerate(jobs)]
    rmixes = spec.resource_mixes
    rspec = spec.rspec
    if rspec is None and any(rm is not None for rm in rmixes):
        raise ValueError(
            "resource_mixes needs GridSpec.resources")
    stamped = {}
    for key, jobs in tenanted.items():
        for rm, fracs in enumerate(rmixes):
            stamped[key + (rm,)] = jobs if fracs is None else \
                _stamp_demand(jobs, rspec, fracs)
    cells = list(itertools.product(
        spec.policies, spec.backfill_modes, spec.arrival_factors,
        spec.seeds, spec.flex_factors, range(len(mixes)),
        range(len(rmixes))))
    streams = [stamped[(lo, se, fl, m, rm)]
               for _, _, lo, se, fl, m, rm in cells]
    tenancy = any(mix is not None for mix in mixes)
    batch, valid = pad_streams(streams, spec.n_pe,
                               with_tenant=tenancy,
                               extra_demand=(rspec.R - 1
                                             if rspec else 0))
    pids = jnp.asarray([policy_index(p) for p, *_ in cells],
                       jnp.int32)
    backfill = tuple(m for _, m, *_ in cells)
    if all(m == "none" for m in backfill):
        backfill = "none"          # keep the classic Q == 0 graphs
    session = ReservationService(ServiceConfig(
        n_pe=spec.n_pe, lanes=len(cells), capacity=capacity,
        pending_capacity=pending_capacity, use_kernel=use_kernel,
        backfill=backfill, backfill_queue=spec.park_capacity,
        chunk_size=None, placement=placement, donate=donate,
        resources=spec.resources,
        tenants=(tuple(mixes[c[-2]] for c in cells)
                 if tenancy else None))).session()
    t0 = _time.perf_counter()
    res = session.offer((batch, valid), policy=pids)
    dec = res.decision
    n_acc, n_val, acc_rate, slowdown, util = grid_reductions(
        dec, batch, valid, spec.n_pe)        # syncs the device
    wall = _time.perf_counter() - t0
    result = GridResult(
        policies=tuple(p.value for p in spec.policies),
        arrival_factors=spec.arrival_factors,
        seeds=spec.seeds,
        flex_factors=spec.flex_factors,
        backfill_modes=spec.backfill_modes,
        acceptance=acc_rate.reshape(shape),
        slowdown=slowdown.reshape(shape),
        utilization=util.reshape(shape),
        n_jobs=n_val.reshape(shape).astype(int),
        n_accepted=n_acc.reshape(shape).astype(int),
        wall_seconds=wall,
    )
    if record_decisions or cross_check:
        accepted = np.asarray(dec.accepted)
        starts = np.asarray(dec.t_s)
        traces: List[List[Tuple[bool, int]]] = [
            [(bool(accepted[c, i]), int(starts[c, i]))
             for i in range(len(streams[c]))]
            for c in range(len(cells))]
        if record_decisions:
            arr = np.empty(len(cells), dtype=object)
            for c in range(len(cells)):
                arr[c] = traces[c]
            result.decisions = arr.reshape(shape).tolist()
    if cross_check:
        _cross_check_cells(cells, mixes, streams, traces, spec.n_pe,
                           spec.park_capacity, rspec)
    return result


def _stamp_demand(jobs, rspec, fracs):
    """Stamp a per-resource demand vector onto each job.

    Secondary-plane demand scales with the job's PE fraction:
    ``demand[r] = min(units[r], round(f_r * units[r] * n_pe / n_pe0))``
    — an ``f_r`` of 1.0 means a whole-machine job wants the whole
    plane, clamped to the plane size.
    """
    if len(fracs) != rspec.R - 1:
        raise ValueError(
            f"resource mix has {len(fracs)} fractions for "
            f"{rspec.R - 1} secondary resources")
    out = []
    for j in jobs:
        tail = tuple(
            min(rspec.units[r + 1],
                max(0, int(round(float(f) * rspec.units[r + 1]
                                 * (j.n_pe / rspec.n_pe)))))
            for r, f in enumerate(fracs))
        out.append(dataclasses.replace(j, demand=(j.n_pe,) + tail))
    return out


def _cross_check_cells(cells, mixes, streams, traces, n_pe: int,
                       park_capacity: int, rspec=None) -> None:
    """Assert every cell is decision-identical to its host oracle."""
    from repro.core.hostsched import (BackfillOracle,
                                      MultiResourceOracle,
                                      TenantOracle)
    from repro.sim.simulator import simulate

    for c, (policy, mode, load, seed, flex, m, rm) in enumerate(cells):
        mix = mixes[m]
        if rspec is not None:
            if mix is not None:
                raise NotImplementedError(
                    "cross_check with both tenant_mixes and "
                    "resources is not supported (no multi-resource "
                    "tenant oracle)")
            ref = MultiResourceOracle(
                rspec, policy, mode,
                park_capacity=park_capacity).run(streams[c])
        elif mix is not None:
            orc = TenantOracle(n_pe, policy, mode, mix,
                               park_capacity=park_capacity)
            ref = [orc.admit(r)[:2] for r in streams[c]]
        elif mode == "none":
            ref = simulate(streams[c], n_pe, policy, engine="host",
                           record_decisions=True).decisions
        else:
            ref = BackfillOracle(
                n_pe, policy, mode,
                park_capacity=park_capacity).run(streams[c])
        if ref != traces[c]:
            diff = [i for i, (x, y) in
                    enumerate(zip(ref, traces[c])) if x != y]
            raise AssertionError(
                f"grid cell (policy={policy.value}, backfill={mode}, "
                f"load={load}, seed={seed}, flex={flex}, "
                f"tenant_mix={m}, resource_mix={rm}) diverges "
                f"from the host oracle at job indices {diff[:10]} "
                f"({len(diff)}/{len(streams[c])} total)")
