"""Section-6 sweep grids as one vmapped device dispatch (DESIGN.md §4).

The paper evaluates its seven policies by sequentially simulating a
grid of job sizes, durations, loads and flexibilities.  With the
functional core's ensemble axis (:mod:`repro.core.ensemble`) that grid
— policies × loads × seeds × flexibilities — becomes *lanes of one
vmapped scan*: every cell's request stream is materialised on the host
(:mod:`repro.sim.workload`), padded to a common fixed shape, stacked,
and offered to one ensemble :class:`repro.api.Session` (lanes =
cells, one-shot mode) in a single jitted dispatch.  The acceptance /
slowdown / utilization metrics are reduced on-device and returned
stacked as a :class:`~repro.sim.metrics.GridResult`.

The host event loop (:func:`repro.sim.simulator.simulate`) remains the
oracle: ``cross_check=True`` asserts per-job decision identity for
every cell, exactly as ``simulate_batched`` does for a single stream.
"""
from __future__ import annotations

import dataclasses
import itertools
import time as _time
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.api import ReservationService, ServiceConfig
from repro.core import batch as batch_lib
from repro.core.batch import RequestBatch, pad_streams
from repro.core.policies import policy_index
from repro.core.types import ALL_POLICIES, Policy
from repro.sim.metrics import GridResult
from repro.sim.workload import WorkloadParams, generate_filtered


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """The experiment matrix: policies × loads × seeds × flexibilities.

    ``arrival_factors`` rescale arrivals (higher = heavier load, paper
    Figs. 4-5); ``flex_factors`` set both the AR-time and deadline
    factor (Figs. 6-7).  ``base`` supplies every other workload knob.
    """

    policies: Tuple[Policy, ...] = ALL_POLICIES
    arrival_factors: Tuple[float, ...] = (0.75, 1.0, 1.25)
    seeds: Tuple[int, ...] = (0, 1, 2)
    flex_factors: Tuple[float, ...] = (3.0,)
    base: WorkloadParams = WorkloadParams()
    n_pe: int = 64
    n_jobs: int = 200

    @property
    def shape(self) -> Tuple[int, int, int, int]:
        return (len(self.policies), len(self.arrival_factors),
                len(self.seeds), len(self.flex_factors))

    @property
    def n_cells(self) -> int:
        return int(np.prod(self.shape))

    def workload_params(self, load: float, seed: int,
                        flex: float) -> WorkloadParams:
        return self.base.replace(
            n_jobs=self.n_jobs, n_pe=self.n_pe, arrival_factor=load,
            seed=seed, artime_factor=flex, deadline_factor=flex)


def _grid_metrics(dec: batch_lib.Decision, batch: RequestBatch,
                  valid: np.ndarray, n_pe: int):
    """Per-cell metric reductions, computed on-device then synced once."""
    v = jnp.asarray(valid)
    acc = dec.accepted & v                             # [C, N]
    n_acc = jnp.sum(acc, axis=1)
    n_val = jnp.maximum(jnp.sum(v, axis=1), 1)
    t_du = batch.t_du.astype(jnp.float32)
    wait = (dec.t_s - batch.t_r + batch.t_du).astype(jnp.float32)
    slow = jnp.where(acc, wait / jnp.maximum(t_du, 1), 0.0)
    slowdown = jnp.sum(slow, axis=1) / jnp.maximum(n_acc, 1)
    slowdown = jnp.where(n_acc > 0, slowdown, jnp.nan)
    # accumulate PE-seconds in f32: paper-scale cells (~1e11) overflow
    # an int32 sum, and utilization is a ratio so 1e-7 relative error
    # is immaterial
    area = jnp.sum(jnp.where(
        acc, (batch.n_pe * batch.t_du).astype(jnp.float32), 0.0),
        axis=1)
    t_a = jnp.where(v, batch.t_a, 0)
    first = jnp.min(jnp.where(v, batch.t_a, jnp.int32(2**31 - 1)),
                    axis=1)
    span = jnp.maximum(jnp.max(t_a, axis=1), 1) - first + 1
    util = area.astype(jnp.float32) / (n_pe * span.astype(jnp.float32))
    return (np.asarray(n_acc), np.asarray(jnp.sum(v, axis=1)),
            np.asarray(n_acc / n_val.astype(jnp.float32)),
            np.asarray(slowdown), np.asarray(util))


def simulate_grid(
    spec: Optional[GridSpec] = None,
    *,
    capacity: int = 128,
    pending_capacity: int = 256,
    use_kernel: bool = False,
    cross_check: bool = False,
    record_decisions: bool = False,
    **overrides,
) -> GridResult:
    """Run the whole experiment matrix as one vmapped on-device scan.

    Each (load, seed, flexibility) workload is generated once and
    shared by all policies — the paper's setup.  All cells admit in
    lockstep via :func:`repro.core.ensemble.admit_stream_ensemble_auto`
    (one growth covers the worst lane), and the stacked metrics come
    back as a :class:`GridResult` indexed ``[policy, load, seed,
    flex]``.  ``cross_check=True`` re-runs every cell on the host
    event loop and asserts per-job decision identity.
    """
    spec = dataclasses.replace(spec or GridSpec(), **overrides)
    P, L, S, F = spec.shape
    # one workload per (load, seed, flex), shared across policies
    workloads = {}
    for load, seed, flex in itertools.product(
            spec.arrival_factors, spec.seeds, spec.flex_factors):
        jobs = generate_filtered(
            spec.workload_params(load, seed, flex), max_pe=spec.n_pe)
        workloads[(load, seed, flex)] = sorted(
            jobs, key=lambda j: j.t_a)
    cells = list(itertools.product(
        spec.policies, spec.arrival_factors, spec.seeds,
        spec.flex_factors))
    streams = [workloads[(lo, se, fl)] for _, lo, se, fl in cells]
    batch, valid = pad_streams(streams, spec.n_pe)
    pids = jnp.asarray([policy_index(p) for p, _, _, _ in cells],
                       jnp.int32)
    session = ReservationService(ServiceConfig(
        n_pe=spec.n_pe, lanes=len(cells), capacity=capacity,
        pending_capacity=pending_capacity, use_kernel=use_kernel,
        chunk_size=None)).session()
    t0 = _time.perf_counter()
    res = session.offer((batch, valid), policy=pids)
    dec = res.decision
    n_acc, n_val, acc_rate, slowdown, util = _grid_metrics(
        dec, batch, valid, spec.n_pe)        # syncs the device
    wall = _time.perf_counter() - t0
    result = GridResult(
        policies=tuple(p.value for p in spec.policies),
        arrival_factors=spec.arrival_factors,
        seeds=spec.seeds,
        flex_factors=spec.flex_factors,
        acceptance=acc_rate.reshape(P, L, S, F),
        slowdown=slowdown.reshape(P, L, S, F),
        utilization=util.reshape(P, L, S, F),
        n_jobs=n_val.reshape(P, L, S, F).astype(int),
        n_accepted=n_acc.reshape(P, L, S, F).astype(int),
        wall_seconds=wall,
    )
    if record_decisions or cross_check:
        accepted = np.asarray(dec.accepted)
        starts = np.asarray(dec.t_s)
        traces: List[List[Tuple[bool, int]]] = [
            [(bool(accepted[c, i]), int(starts[c, i]))
             for i in range(len(streams[c]))]
            for c in range(len(cells))]
        if record_decisions:
            arr = np.empty(len(cells), dtype=object)
            for c in range(len(cells)):
                arr[c] = traces[c]
            result.decisions = arr.reshape(P, L, S, F).tolist()
    if cross_check:
        _cross_check_cells(cells, streams, traces, spec.n_pe)
    return result


def _cross_check_cells(cells, streams, traces, n_pe: int) -> None:
    """Assert every cell is decision-identical to the host event loop."""
    from repro.sim.simulator import simulate

    for c, (policy, load, seed, flex) in enumerate(cells):
        ref = simulate(streams[c], n_pe, policy, engine="host",
                       record_decisions=True)
        if ref.decisions != traces[c]:
            diff = [i for i, (x, y) in
                    enumerate(zip(ref.decisions, traces[c])) if x != y]
            raise AssertionError(
                f"grid cell (policy={policy.value}, load={load}, "
                f"seed={seed}, flex={flex}) diverges from the host "
                f"loop at job indices {diff[:10]} "
                f"({len(diff)}/{len(streams[c])} total)")
