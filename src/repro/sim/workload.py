"""Feitelson-Lublin workload model with LANL-CM5 parameters (Section 6.1).

Generates deadline-constrained AR requests the way the paper does:

* **Sizes** — the two-stage uniform distribution over ``log2(size)``
  with ``(ULow, UMed, UHi, Uprob) = (4.5, UMed, 10, 0.82)``; all jobs
  parallel, sizes powers of two in ``[32, 1024]`` (LANL-CM5 partitions).
* **Runtimes** — hyper-Gamma over ``ln(runtime)`` whose mixture weight
  decreases with job size (size/runtime correlation), snapped to the
  paper's six discrete values ``{60, 300, 900, 1800, 3600, 10800}`` s.
* **Arrivals** — Gamma inter-arrivals modulated by a daily cycle (the
  "combined model"), with the base rate calibrated so the *offered
  load* at ``arrival_factor = 1`` hits ``target_load`` of the machine.
  The ``arrival factor`` then rescales arrival times ``t -> t / af``
  exactly as in the paper.
* **AR/deadline factors** — ``t_r = t_a + artime_factor * U * t_du`` and
  ``t_dl = t_r + (1 + deadline_factor * U) * t_du``.

Calibration note (EXPERIMENTS.md §Fidelity): the paper inherits exact
hyper-Gamma and arrival constants from Lublin's model fitted to the
LANL-CM5 log, then modifies runtimes to the six discrete values.  Those
exact constants are not recoverable from the paper text, so this module
keeps the distribution *families* and the size/runtime correlation and
calibrates the base arrival rate to a target offered load; the paper's
qualitative claims (policy orderings, monotone trends) are what the
reproduction validates.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.types import ARRequest

RUNTIME_VALUES = np.array([60, 300, 900, 1800, 3600, 10800], dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class WorkloadParams:
    """Knobs of Section 6.1, defaults = the paper's defaults."""

    n_jobs: int = 10_000
    n_pe: int = 1024
    # two-stage uniform over log2(size)
    u_low: float = 4.5
    u_med: float = 7.0
    u_hi: float = 10.0
    u_prob: float = 0.82
    # hyper-Gamma over ln(runtime); mixture weight p(size) decreasing
    g1_shape: float = 4.2
    g1_scale: float = 0.94
    g2_shape: float = 312.0
    g2_scale: float = 0.03
    p_slope: float = -0.075     # p = clip(p_slope * log2(size) + p_icept)
    p_icept: float = 1.1
    # arrivals
    arrival_shape: float = 2.0  # Gamma shape of inter-arrival times
    daily_cycle_amp: float = 0.4
    target_load: float = 0.75   # offered load at arrival_factor == 1
    arrival_factor: float = 1.0
    # AR / deadline flexibility
    artime_factor: float = 3.0
    deadline_factor: float = 3.0
    seed: int = 0

    def replace(self, **kw) -> "WorkloadParams":
        return dataclasses.replace(self, **kw)


def sample_sizes(rng: np.random.Generator, p: WorkloadParams,
                 n: int) -> np.ndarray:
    stage = rng.random(n) < p.u_prob
    lo = rng.uniform(p.u_low, p.u_med, size=n)
    hi = rng.uniform(p.u_med, p.u_hi, size=n)
    log2s = np.where(stage, lo, hi)
    k = np.clip(np.rint(log2s), np.ceil(p.u_low), np.floor(p.u_hi))
    return (2 ** k).astype(np.int64)


def sample_runtimes(rng: np.random.Generator, p: WorkloadParams,
                    sizes: np.ndarray) -> np.ndarray:
    n = sizes.shape[0]
    prob_short = np.clip(
        p.p_slope * np.log2(sizes) + p.p_icept, 0.05, 0.95)
    short = rng.random(n) < prob_short
    ln_r = np.where(
        short,
        rng.gamma(p.g1_shape, p.g1_scale, size=n),
        rng.gamma(p.g2_shape, p.g2_scale, size=n),
    )
    # snap to the paper's six values, nearest in log space
    dist = np.abs(ln_r[:, None] - np.log(RUNTIME_VALUES)[None, :])
    return RUNTIME_VALUES[np.argmin(dist, axis=1)]


def mean_job_area(p: WorkloadParams, n_probe: int = 20_000) -> float:
    """E[size * runtime] for calibrating the base arrival rate."""
    rng = np.random.default_rng(10_000 + p.seed)
    sizes = sample_sizes(rng, p, n_probe)
    runtimes = sample_runtimes(rng, p, sizes)
    return float(np.mean(sizes * runtimes))


def sample_arrivals(rng: np.random.Generator, p: WorkloadParams,
                    n: int) -> np.ndarray:
    """Arrival times (seconds): Gamma inter-arrivals + daily cycle."""
    mean_ia = mean_job_area(p) / (p.n_pe * p.target_load)
    scale = mean_ia / p.arrival_shape
    ia = rng.gamma(p.arrival_shape, scale, size=n)
    # daily rhythm: stretch inter-arrivals at "night", compress at "day"
    t = np.cumsum(ia)
    cyc = 1.0 + p.daily_cycle_amp * np.sin(2 * np.pi * t / 86_400.0)
    ia = ia / np.maximum(cyc, 0.1)
    arrivals = np.cumsum(ia)
    return arrivals / p.arrival_factor


def generate(params: Optional[WorkloadParams] = None,
             **overrides) -> List[ARRequest]:
    """Generate the AR job stream for one experiment."""
    p = (params or WorkloadParams()).replace(**overrides) \
        if overrides else (params or WorkloadParams())
    rng = np.random.default_rng(p.seed)
    n = p.n_jobs
    arrivals = np.rint(sample_arrivals(rng, p, n)).astype(np.int64)
    sizes = sample_sizes(rng, p, n)
    runtimes = sample_runtimes(rng, p, sizes)
    u_ar = rng.random(n)
    u_dl = rng.random(n)
    t_r = arrivals + np.rint(p.artime_factor * u_ar * runtimes).astype(
        np.int64)
    t_dl = t_r + runtimes + np.rint(
        p.deadline_factor * u_dl * runtimes).astype(np.int64)
    return [
        ARRequest(t_a=int(arrivals[i]), t_r=int(t_r[i]),
                  t_du=int(runtimes[i]), t_dl=int(t_dl[i]),
                  n_pe=int(sizes[i]))
        for i in range(n)
    ]


def generate_filtered(params: Optional[WorkloadParams] = None,
                      max_pe: Optional[int] = None,
                      **overrides) -> List[ARRequest]:
    """:func:`generate`, dropping jobs wider than the machine.

    The size distribution is unconditional, so scaled-down machines
    (``n_pe`` below the LANL-CM5 1024) would otherwise see requests
    that can never fit; every sweep/benchmark applies this filter.
    """
    p = (params or WorkloadParams()).replace(**overrides) \
        if overrides else (params or WorkloadParams())
    cap = p.n_pe if max_pe is None else max_pe
    return [j for j in generate(p) if j.n_pe <= cap]
