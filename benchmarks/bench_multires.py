"""Multi-resource timeline overhead: R=1 parity cost and the R curve.

The vector timeline (DESIGN.md §11) concatenates one packed bitplane
per resource on the occupancy word axis, and the fit test AND-reduces
per-plane feasibility.  Two claims are measured into
``BENCH_multires.json``:

* ``r1`` vs ``legacy``: warm requests/sec of the same ring-chunked
  offer stream on an ``rspec=(n_pe,)`` session vs a plain one.  The
  R=1 layout is byte-identical to the legacy timeline, so this ratio
  prices only the rspec code path (demand columns in the ring, the
  masked popcount contraction) and must stay a small constant factor.
* ``r2`` / ``r4``: the cost curve as planes are added.  Each plane
  adds words to every occupancy row and one more feasibility reduce,
  so cost should grow roughly linearly in total words — the gate pins
  the R=4 ratio so a superlinear regression (e.g. a per-plane rescan)
  fails the band.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Dict, List, Optional, Tuple

from repro.api import ReservationService, ServiceConfig
from repro.core.types import Policy
from repro.sim import WorkloadParams, generate

_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_MULTIRES_PATH = str(_ROOT / "BENCH_multires.json")

#: secondary-plane unit counts of the R=2 / R=4 variants
R2_TAIL: Tuple[int, ...] = (8,)
R4_TAIL: Tuple[int, ...] = (8, 4, 16)


def _jobs(n_jobs: int, n_pe: int, seed: int):
    return sorted(
        [j for j in generate(WorkloadParams(
            n_jobs=n_jobs, n_pe=n_pe, seed=seed,
            u_low=2.0, u_med=4.0, u_hi=6.0)) if j.n_pe <= n_pe],
        key=lambda j: j.t_a)


def _stamp(jobs, n_pe: int, tail: Tuple[int, ...]):
    """Half-intensity secondary demand, scaled by the job's PE share."""
    out = []
    for j in jobs:
        dem = tuple(
            min(u, max(0, int(round(0.5 * u * (j.n_pe / n_pe)))))
            for u in tail)
        out.append(dataclasses.replace(j, demand=(j.n_pe,) + dem))
    return out


def multires_throughput(n_jobs: int = 240, n_pe: int = 64,
                        chunk: int = 64, seed: int = 0,
                        repeats: int = 5,
                        out_path: Optional[str] = BENCH_MULTIRES_PATH
                        ) -> List[Dict]:
    """Warm ring-chunked offer throughput across resource counts."""
    from benchmarks._measure import median_wall

    base = _jobs(n_jobs, n_pe, seed)
    variants = [
        ("legacy", None, base),
        ("r1", (n_pe,), base),
        ("r2", (n_pe,) + R2_TAIL, _stamp(base, n_pe, R2_TAIL)),
        ("r4", (n_pe,) + R4_TAIL, _stamp(base, n_pe, R4_TAIL)),
    ]

    def run_stream(resources, jobs) -> float:
        sess = ReservationService(ServiceConfig(
            n_pe=n_pe, policy=Policy.PE_W, capacity=128,
            pending_capacity=256, chunk_size=chunk,
            ring_capacity=2 * chunk, resources=resources)).session()
        t0 = time.perf_counter()
        i = 0
        while i < len(jobs):
            sess.offer(jobs[i:i + chunk])
            i += chunk
        sess.metrics()          # decision + counter sync
        return time.perf_counter() - t0

    walls = {name: median_wall(lambda r=res, j=jobs: run_stream(r, j),
                               repeats)
             for name, res, jobs in variants}
    n = len(base)
    legacy = walls["legacy"]
    rows = [
        dict(variant=name,
             n_resources=1 if res is None else len(res),
             occ_words=((n_pe + 31) // 32 if res is None else
                        sum((u + 31) // 32 for u in res)),
             warm_req_per_s=round(n / walls[name], 1),
             cost_vs_legacy=round(walls[name] / max(legacy, 1e-9), 3))
        for name, res, _ in variants]
    if out_path:
        with open(out_path, "w") as fh:
            json.dump({
                "description": "multi-resource timeline step cost: "
                               "R=1 parity overhead and the plane-"
                               "count cost curve",
                "n_jobs": n, "n_pe": n_pe, "chunk": chunk,
                "r2_tail": list(R2_TAIL), "r4_tail": list(R4_TAIL),
                "rows": rows,
            }, fh, indent=2)
            fh.write("\n")
    return rows


if __name__ == "__main__":
    for row in multires_throughput():
        print(row)
