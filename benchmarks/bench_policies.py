"""Paper Section 6 experiments: Figures 2-7.

Three sweeps over the seven policies, each workload shared across
policies exactly as the paper does.  ``n_jobs`` defaults to a reduced
size for the benchmark harness; ``examples/reproduce_paper.py`` runs
the full 10^4-job version with per-seed 95% CIs.
"""
from __future__ import annotations

import itertools
import json
import pathlib
from typing import Dict, List, Optional

from repro.core.types import ALL_POLICIES
from repro.sim import (
    GridSpec,
    WorkloadParams,
    generate,
    run_policies,
    simulate,
    simulate_batched,
    simulate_grid,
)

N_PE = 1024

# the tracked perf-trajectory artifacts live at the repo root,
# independent of the benchmark's working directory
_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_ADMISSION_PATH = str(_ROOT / "BENCH_admission.json")
BENCH_SWEEP_PATH = str(_ROOT / "BENCH_sweep.json")


def _sweep(param_sets: List[Dict], n_jobs: int, seed: int
           ) -> List[Dict]:
    rows = []
    for ps in param_sets:
        jobs = generate(WorkloadParams(n_jobs=n_jobs, seed=seed,
                                       **ps))
        for r in run_policies(jobs, N_PE, ALL_POLICIES):
            rows.append({**ps, "policy": r.policy,
                         "acceptance": round(r.acceptance_rate, 4),
                         "slowdown": round(r.avg_slowdown, 4),
                         "util": round(r.utilization, 4),
                         "sched_wall_s": round(r.wall_seconds, 3)})
    return rows


def umed_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 2-3: acceptance/slowdown vs UMed in {5..9}."""
    return _sweep([{"u_med": float(u)} for u in (5, 6, 7, 8, 9)],
                  n_jobs, seed)


def load_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 4-5: acceptance/slowdown vs arrival factor."""
    return _sweep(
        [{"arrival_factor": f} for f in (0.5, 0.75, 1.0, 1.25, 1.5)],
        n_jobs, seed)


def flex_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 6-7: acceptance/slowdown vs {artime, deadline} factor."""
    return _sweep(
        [{"artime_factor": float(f), "deadline_factor": float(f)}
         for f in (1, 2, 3, 4, 5)],
        n_jobs, seed)


def admission_throughput(n_jobs: int = 240, n_pe: int = 64,
                         seed: int = 0, capacity: int = 32,
                         repeats: int = 5,
                         out_path: Optional[str] = BENCH_ADMISSION_PATH
                         ) -> List[Dict]:
    """Admissions/sec: per-request loops vs the scanned device path.

    Three variants over the same workload and all seven policies: the
    host numpy loop, the per-request device loop (one host round-trip
    per job), and the fused ``admit_stream`` scan (DESIGN.md §3/§7).
    Device variants start at a modest ``capacity`` and rely on the
    grow-once overflow protocol (included in wall time): static shapes
    then track the workload's live records instead of a pessimistic
    preset, which is where the sort-free hot path gets its constant
    factors.  Wall times are warmed-up medians of ``repeats`` runs;
    each device_stream row carries ``speedup_vs_pr4`` /
    ``speedup_vs_pr5`` against the frozen prior-PR baselines
    (:mod:`benchmarks._measure`).
    """
    from benchmarks._measure import (
        PR4_ADMISSION_STREAM, PR5_ADMISSION_STREAM,
        PR6_ADMISSION_STREAM, median_wall, speedup_vs_pr4,
        speedup_vs_pr5, speedup_vs_pr6)

    jobs = generate(WorkloadParams(n_jobs=n_jobs, n_pe=n_pe, seed=seed,
                                   u_low=2.0, u_med=4.0, u_hi=6.0))
    jobs = [j for j in jobs if j.n_pe <= n_pe]
    rows: List[Dict] = []
    for pol in ALL_POLICIES:
        acc = {}

        def _wall(res, name):
            acc[name] = res.acceptance_rate
            return res.wall_seconds

        variants = {
            "host_loop": lambda p=pol: _wall(simulate(
                jobs, n_pe, p, engine="host"), "host_loop"),
            "device_loop": lambda p=pol: _wall(simulate(
                jobs, n_pe, p, engine="device",
                engine_kwargs={"capacity": capacity}), "device_loop"),
            "device_stream": lambda p=pol: _wall(simulate_batched(
                jobs, n_pe, p, capacity=capacity), "device_stream"),
        }
        row: Dict = {"policy": pol.value}
        for name, fn in variants.items():
            wall = median_wall(fn, repeats)
            row[f"{name}_adm_per_s"] = round(
                len(jobs) / max(wall, 1e-9), 1)
        row["acceptance"] = round(acc["device_stream"], 4)
        row["stream_speedup_vs_device_loop"] = round(
            row["device_stream_adm_per_s"]
            / max(row["device_loop_adm_per_s"], 1e-9), 1)
        row["stream_speedup_vs_host"] = round(
            row["device_stream_adm_per_s"]
            / max(row["host_loop_adm_per_s"], 1e-9), 2)
        row["speedup_vs_pr4"] = speedup_vs_pr4(
            row["device_stream_adm_per_s"],
            PR4_ADMISSION_STREAM[pol.value])
        row["speedup_vs_pr5"] = speedup_vs_pr5(
            row["device_stream_adm_per_s"],
            PR5_ADMISSION_STREAM[pol.value])
        row["speedup_vs_pr6"] = speedup_vs_pr6(
            row["device_stream_adm_per_s"],
            PR6_ADMISSION_STREAM[pol.value])
        rows.append(row)
    if out_path:
        payload = {
            "bench": "admission_throughput",
            "n_jobs": len(jobs), "n_pe": n_pe, "seed": seed,
            "capacity": capacity, "repeats": repeats,
            "note": ("admissions/sec, warmed-up median of "
                     f"{repeats} runs; wall time counts scheduler "
                     "work only, grow-once overflow sizing included; "
                     "device variants start at capacity "
                     f"{capacity} (occupancy-aware, DESIGN.md §7); "
                     "speedup_vs_pr4/pr5 compare device_stream to the "
                     "frozen prior-PR rows"),
            "rows": rows,
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    return rows


def sweep_throughput(n_jobs: int = 120, n_pe: int = 64,
                     capacity: int = 32, repeats: int = 5,
                     out_path: Optional[str] = BENCH_SWEEP_PATH
                     ) -> List[Dict]:
    """Grid cells/sec: host loop vs per-cell scan vs vmapped grid.

    One Section-6 experiment matrix (7 policies × 3 loads × 3 seeds =
    63 cells, workloads shared across policies) evaluated three ways:

    * ``host_loop`` — the classic per-cell host event loop;
    * ``device_scan`` — one ``admit_stream`` scan per cell, cells
      dispatched sequentially from the host;
    * ``vmapped_grid`` — all cells as lanes of one vmapped scan
      (``simulate_grid``, DESIGN.md §4).

    Device variants start at a modest ``capacity`` with grow-once
    overflow sizing included in wall time (DESIGN.md §7).  Wall times
    are warmed-up *medians* of ``repeats`` runs — the pre-PR 5
    protocol published a single steady-state sample, noisy enough on
    shared runners to move the crossover numbers by tens of percent —
    and the full grid geometry is recorded in the JSON so future
    trajectories stay comparable.
    """
    from benchmarks._measure import (
        PR4_SWEEP_CELLS, PR5_SWEEP_CELLS, PR6_SWEEP_CELLS,
        median_wall, speedup_vs_pr4, speedup_vs_pr5, speedup_vs_pr6)
    from repro.sim.workload import generate_filtered

    spec = GridSpec(
        policies=ALL_POLICIES, arrival_factors=(1.0, 1.5, 2.0),
        seeds=(0, 1, 2), flex_factors=(3.0,),
        base=WorkloadParams(u_low=2.0, u_med=4.0, u_hi=6.0),
        n_pe=n_pe, n_jobs=n_jobs)
    workloads = {
        (lo, se, fl): generate_filtered(
            spec.workload_params(lo, se, fl), max_pe=n_pe)
        for lo, se, fl in itertools.product(
            spec.arrival_factors, spec.seeds, spec.flex_factors)}
    cells = [(pol, key) for pol in spec.policies for key in workloads]

    def host_loop() -> float:
        return sum(
            simulate(workloads[key], n_pe, pol,
                     engine="host").wall_seconds
            for pol, key in cells)

    def device_scan() -> float:
        return sum(
            simulate_batched(workloads[key], n_pe, pol,
                             capacity=capacity).wall_seconds
            for pol, key in cells)

    def vmapped_grid() -> float:
        return simulate_grid(spec, capacity=capacity).wall_seconds

    rows: List[Dict] = []
    walls: Dict[str, float] = {}
    for name, fn in (("host_loop", host_loop),
                     ("device_scan", device_scan),
                     ("vmapped_grid", vmapped_grid)):
        wall = median_wall(fn, repeats)
        walls[name] = wall
        rows.append({
            "variant": name,
            "n_cells": len(cells),
            "wall_s": round(wall, 4),
            "cells_per_s": round(len(cells) / max(wall, 1e-9), 2),
        })
    for row in rows:
        row["speedup_vs_host_loop"] = round(
            walls["host_loop"] / max(walls[row["variant"]], 1e-9), 2)
        row["speedup_vs_pr4"] = speedup_vs_pr4(
            row["cells_per_s"], PR4_SWEEP_CELLS[row["variant"]])
        row["speedup_vs_pr5"] = speedup_vs_pr5(
            row["cells_per_s"], PR5_SWEEP_CELLS[row["variant"]])
        row["speedup_vs_pr6"] = speedup_vs_pr6(
            row["cells_per_s"], PR6_SWEEP_CELLS[row["variant"]])
    if out_path:
        payload = {
            "bench": "sweep_throughput",
            "grid": {"policies": len(spec.policies),
                     "arrival_factors": list(spec.arrival_factors),
                     "seeds": list(spec.seeds),
                     "flex_factors": list(spec.flex_factors),
                     "n_jobs": n_jobs, "n_pe": n_pe,
                     "n_cells": len(cells)},
            "capacity": capacity, "repeats": repeats,
            "note": ("Section-6 grid cells/sec, warmed-up median of "
                     f"{repeats} runs; wall time counts scheduler/"
                     "dispatch work only, grow-once overflow sizing "
                     "included (device variants start at capacity "
                     f"{capacity}); speedup_vs_pr4/pr5 compare to "
                     "the frozen prior-PR rows"),
            "rows": rows,
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    return rows
