"""Paper Section 6 experiments: Figures 2-7.

Three sweeps over the seven policies, each workload shared across
policies exactly as the paper does.  ``n_jobs`` defaults to a reduced
size for the benchmark harness; ``examples/reproduce_paper.py`` runs
the full 10^4-job version with per-seed 95% CIs.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.types import ALL_POLICIES
from repro.sim import SimResult, WorkloadParams, generate, run_policies

N_PE = 1024


def _sweep(param_sets: List[Dict], n_jobs: int, seed: int
           ) -> List[Dict]:
    rows = []
    for ps in param_sets:
        jobs = generate(WorkloadParams(n_jobs=n_jobs, seed=seed,
                                       **ps))
        for r in run_policies(jobs, N_PE, ALL_POLICIES):
            rows.append({**ps, "policy": r.policy,
                         "acceptance": round(r.acceptance_rate, 4),
                         "slowdown": round(r.avg_slowdown, 4),
                         "util": round(r.utilization, 4),
                         "sched_wall_s": round(r.wall_seconds, 3)})
    return rows


def umed_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 2-3: acceptance/slowdown vs UMed in {5..9}."""
    return _sweep([{"u_med": float(u)} for u in (5, 6, 7, 8, 9)],
                  n_jobs, seed)


def load_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 4-5: acceptance/slowdown vs arrival factor."""
    return _sweep(
        [{"arrival_factor": f} for f in (0.5, 0.75, 1.0, 1.25, 1.5)],
        n_jobs, seed)


def flex_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 6-7: acceptance/slowdown vs {artime, deadline} factor."""
    return _sweep(
        [{"artime_factor": float(f), "deadline_factor": float(f)}
         for f in (1, 2, 3, 4, 5)],
        n_jobs, seed)
