"""Paper Section 6 experiments: Figures 2-7.

Three sweeps over the seven policies, each workload shared across
policies exactly as the paper does.  ``n_jobs`` defaults to a reduced
size for the benchmark harness; ``examples/reproduce_paper.py`` runs
the full 10^4-job version with per-seed 95% CIs.
"""
from __future__ import annotations

import itertools
import json
import pathlib
from typing import Dict, List, Optional

from repro.core.types import ALL_POLICIES
from repro.sim import (
    GridSpec,
    WorkloadParams,
    generate,
    run_policies,
    simulate,
    simulate_batched,
    simulate_grid,
)

N_PE = 1024

# the tracked perf-trajectory artifacts live at the repo root,
# independent of the benchmark's working directory
_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_ADMISSION_PATH = str(_ROOT / "BENCH_admission.json")
BENCH_SWEEP_PATH = str(_ROOT / "BENCH_sweep.json")


def _sweep(param_sets: List[Dict], n_jobs: int, seed: int
           ) -> List[Dict]:
    rows = []
    for ps in param_sets:
        jobs = generate(WorkloadParams(n_jobs=n_jobs, seed=seed,
                                       **ps))
        for r in run_policies(jobs, N_PE, ALL_POLICIES):
            rows.append({**ps, "policy": r.policy,
                         "acceptance": round(r.acceptance_rate, 4),
                         "slowdown": round(r.avg_slowdown, 4),
                         "util": round(r.utilization, 4),
                         "sched_wall_s": round(r.wall_seconds, 3)})
    return rows


def umed_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 2-3: acceptance/slowdown vs UMed in {5..9}."""
    return _sweep([{"u_med": float(u)} for u in (5, 6, 7, 8, 9)],
                  n_jobs, seed)


def load_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 4-5: acceptance/slowdown vs arrival factor."""
    return _sweep(
        [{"arrival_factor": f} for f in (0.5, 0.75, 1.0, 1.25, 1.5)],
        n_jobs, seed)


def flex_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 6-7: acceptance/slowdown vs {artime, deadline} factor."""
    return _sweep(
        [{"artime_factor": float(f), "deadline_factor": float(f)}
         for f in (1, 2, 3, 4, 5)],
        n_jobs, seed)


def admission_throughput(n_jobs: int = 240, n_pe: int = 64,
                         seed: int = 0, capacity: int = 32,
                         repeats: int = 5,
                         index_tile: Optional[int] = 16,
                         out_path: Optional[str] = BENCH_ADMISSION_PATH
                         ) -> List[Dict]:
    """Admissions/sec: per-request loops vs the scanned device path.

    Three variants over the same workload and all seven policies: the
    host numpy loop, the per-request device loop (one host round-trip
    per job), and the fused ``admit_stream`` scan (DESIGN.md §3/§7)
    with the hierarchical availability index attached
    (``index_tile``, DESIGN.md §12 — decisions are bit-identical to
    the index-free scan; ``None`` measures the index-free graphs).
    Device variants start at a modest ``capacity`` and rely on the
    grow-once overflow protocol (included in wall time): static shapes
    then track the workload's live records instead of a pessimistic
    preset, which is where the sort-free hot path gets its constant
    factors.  Wall times are warmed-up medians of ``repeats`` runs;
    each row carries machine-normalised ``speedup_vs_pr4/5/6/9``
    columns — device_stream over the frozen prior-PR rows scaled by
    the host-geomean :func:`benchmarks._measure.machine_factor`, so
    runner speed divides out of the trajectory.
    """
    from benchmarks._measure import (
        PR4_ADMISSION_STREAM, PR5_ADMISSION_HOST, PR5_ADMISSION_STREAM,
        PR5_STREAM_YARDSTICK_HOST, PR6_ADMISSION_HOST,
        PR6_ADMISSION_STREAM, PR9_ADMISSION_HOST,
        PR9_ADMISSION_STREAM, machine_factor, median)

    jobs = generate(WorkloadParams(n_jobs=n_jobs, n_pe=n_pe, seed=seed,
                                   u_low=2.0, u_med=4.0, u_hi=6.0))
    jobs = [j for j in jobs if j.n_pe <= n_pe]
    names = ("host_loop", "device_loop", "device_stream")
    acc: Dict = {}

    def _run(pol, name) -> float:
        if name == "host_loop":
            res = simulate(jobs, n_pe, pol, engine="host")
        elif name == "device_loop":
            res = simulate(jobs, n_pe, pol, engine="device",
                           engine_kwargs={"capacity": capacity})
        else:
            res = simulate_batched(jobs, n_pe, pol, capacity=capacity,
                                   index_tile=index_tile)
        acc[(pol.value, name)] = res.acceptance_rate
        return res.wall_seconds

    # warmup round: jit caches + the grow-once overflow fixed point
    for pol in ALL_POLICIES:
        for name in names:
            _run(pol, name)
    # measurement rounds are policy-major: runner speed drifts
    # monotonically over a process's life, so measuring each policy's
    # repeats back-to-back (the old protocol) hands late-ordered
    # policies a systematically slower runner than early ones — and
    # than the frozen cross-PR baselines.  Round-robin spreads every
    # policy and variant uniformly across the process lifetime.
    # the stream runs are ~20x shorter than the loop variants, so
    # their medians are jitter-dominated at the same sample count —
    # oversample them (near-free) to match the loops' precision
    stream_oversample = 3
    walls: Dict = {p.value: {n: [] for n in names}
                   for p in ALL_POLICIES}
    for _ in range(max(repeats, 1)):
        for pol in ALL_POLICIES:
            for name in names:
                n_samp = (stream_oversample
                          if name == "device_stream" else 1)
                for _s in range(n_samp):
                    walls[pol.value][name].append(_run(pol, name))
    rows: List[Dict] = []
    for pol in ALL_POLICIES:
        row: Dict = {"policy": pol.value}
        for name in names:
            wall = median(walls[pol.value][name])
            row[f"{name}_adm_per_s"] = round(
                len(jobs) / max(wall, 1e-9), 1)
        row["acceptance"] = round(acc[(pol.value, "device_stream")], 4)
        row["stream_speedup_vs_device_loop"] = round(
            row["device_stream_adm_per_s"]
            / max(row["device_loop_adm_per_s"], 1e-9), 1)
        row["stream_speedup_vs_host"] = round(
            row["device_stream_adm_per_s"]
            / max(row["host_loop_adm_per_s"], 1e-9), 2)
        rows.append(row)
    # cross-PR speedups: scale every frozen baseline by this runner's
    # host-geomean machine factor, then compare the fresh stream rows
    fresh_hosts = {r["policy"]: r["host_loop_adm_per_s"] for r in rows}
    eras = (
        ("speedup_vs_pr4", PR4_ADMISSION_STREAM, PR5_ADMISSION_HOST),
        ("speedup_vs_pr5", PR5_ADMISSION_STREAM,
         PR5_STREAM_YARDSTICK_HOST),
        ("speedup_vs_pr6", PR6_ADMISSION_STREAM, PR6_ADMISSION_HOST),
        ("speedup_vs_pr9", PR9_ADMISSION_STREAM, PR9_ADMISSION_HOST),
    )
    for col, frozen_stream, frozen_hosts in eras:
        m = machine_factor(fresh_hosts, frozen_hosts)
        for row in rows:
            base = frozen_stream[row["policy"]] * m
            row[col] = round(
                row["device_stream_adm_per_s"] / max(base, 1e-9), 2)
    if out_path:
        payload = {
            "bench": "admission_throughput",
            "n_jobs": len(jobs), "n_pe": n_pe, "seed": seed,
            "capacity": capacity, "repeats": repeats,
            "index_tile": index_tile,
            "note": ("admissions/sec, warmed-up median of "
                     f"{repeats} policy-major round-robin rounds "
                     "(uniform runner-drift exposure per policy); "
                     "wall time counts scheduler "
                     "work only, grow-once overflow sizing included; "
                     "device variants start at capacity "
                     f"{capacity} (occupancy-aware, DESIGN.md §7); "
                     f"device_stream runs index_tile={index_tile} "
                     "(DESIGN.md §12, decisions bit-identical); "
                     "speedup_vs_pr4/5/6/9 compare device_stream to "
                     "the frozen prior-PR rows scaled by the "
                     "host-geomean machine factor"),
            "rows": rows,
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    return rows


def sweep_throughput(n_jobs: int = 120, n_pe: int = 64,
                     capacity: int = 32, repeats: int = 5,
                     out_path: Optional[str] = BENCH_SWEEP_PATH
                     ) -> List[Dict]:
    """Grid cells/sec: host loop vs per-cell scan vs vmapped grid.

    One Section-6 experiment matrix (7 policies × 3 loads × 3 seeds =
    63 cells, workloads shared across policies) evaluated three ways:

    * ``host_loop`` — the classic per-cell host event loop;
    * ``device_scan`` — one ``admit_stream`` scan per cell, cells
      dispatched sequentially from the host;
    * ``vmapped_grid`` — all cells as lanes of one vmapped scan
      (``simulate_grid``, DESIGN.md §4).

    Device variants start at a modest ``capacity`` with grow-once
    overflow sizing included in wall time (DESIGN.md §7).  Wall times
    are warmed-up *medians* of ``repeats`` runs — the pre-PR 5
    protocol published a single steady-state sample, noisy enough on
    shared runners to move the crossover numbers by tens of percent —
    and the full grid geometry is recorded in the JSON so future
    trajectories stay comparable.
    """
    from benchmarks._measure import (
        PR4_SWEEP_CELLS, PR5_SWEEP_CELLS, PR6_SWEEP_CELLS,
        PR9_SWEEP_CELLS, median_wall)
    from repro.sim.workload import generate_filtered

    spec = GridSpec(
        policies=ALL_POLICIES, arrival_factors=(1.0, 1.5, 2.0),
        seeds=(0, 1, 2), flex_factors=(3.0,),
        base=WorkloadParams(u_low=2.0, u_med=4.0, u_hi=6.0),
        n_pe=n_pe, n_jobs=n_jobs)
    workloads = {
        (lo, se, fl): generate_filtered(
            spec.workload_params(lo, se, fl), max_pe=n_pe)
        for lo, se, fl in itertools.product(
            spec.arrival_factors, spec.seeds, spec.flex_factors)}
    cells = [(pol, key) for pol in spec.policies for key in workloads]

    def host_loop() -> float:
        return sum(
            simulate(workloads[key], n_pe, pol,
                     engine="host").wall_seconds
            for pol, key in cells)

    def device_scan() -> float:
        return sum(
            simulate_batched(workloads[key], n_pe, pol,
                             capacity=capacity).wall_seconds
            for pol, key in cells)

    def vmapped_grid() -> float:
        return simulate_grid(spec, capacity=capacity).wall_seconds

    rows: List[Dict] = []
    walls: Dict[str, float] = {}
    for name, fn in (("host_loop", host_loop),
                     ("device_scan", device_scan),
                     ("vmapped_grid", vmapped_grid)):
        wall = median_wall(fn, repeats)
        walls[name] = wall
        rows.append({
            "variant": name,
            "n_cells": len(cells),
            "wall_s": round(wall, 4),
            "cells_per_s": round(len(cells) / max(wall, 1e-9), 2),
        })
    # cross-PR speedups, machine-normalised by the host-loop variant
    # (the one yardstick both runners executed unchanged)
    fresh_host = len(cells) / max(walls["host_loop"], 1e-9)
    eras = (("speedup_vs_pr4", PR4_SWEEP_CELLS),
            ("speedup_vs_pr5", PR5_SWEEP_CELLS),
            ("speedup_vs_pr6", PR6_SWEEP_CELLS),
            ("speedup_vs_pr9", PR9_SWEEP_CELLS))
    for row in rows:
        row["speedup_vs_host_loop"] = round(
            walls["host_loop"] / max(walls[row["variant"]], 1e-9), 2)
        for col, frozen in eras:
            m = fresh_host / max(frozen["host_loop"], 1e-9)
            row[col] = round(
                row["cells_per_s"] / max(frozen[row["variant"]] * m,
                                         1e-9), 2)
    if out_path:
        payload = {
            "bench": "sweep_throughput",
            "grid": {"policies": len(spec.policies),
                     "arrival_factors": list(spec.arrival_factors),
                     "seeds": list(spec.seeds),
                     "flex_factors": list(spec.flex_factors),
                     "n_jobs": n_jobs, "n_pe": n_pe,
                     "n_cells": len(cells)},
            "capacity": capacity, "repeats": repeats,
            "note": ("Section-6 grid cells/sec, warmed-up median of "
                     f"{repeats} runs; wall time counts scheduler/"
                     "dispatch work only, grow-once overflow sizing "
                     "included (device variants start at capacity "
                     f"{capacity}); speedup_vs_pr4/5/6/9 compare to "
                     "the frozen prior-PR rows scaled by the "
                     "host-loop machine factor"),
            "rows": rows,
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    return rows
