"""Paper Section 6 experiments: Figures 2-7.

Three sweeps over the seven policies, each workload shared across
policies exactly as the paper does.  ``n_jobs`` defaults to a reduced
size for the benchmark harness; ``examples/reproduce_paper.py`` runs
the full 10^4-job version with per-seed 95% CIs.
"""
from __future__ import annotations

import itertools
import json
import pathlib
from typing import Dict, List, Optional

from repro.core.types import ALL_POLICIES
from repro.sim import (
    GridSpec,
    WorkloadParams,
    generate,
    run_policies,
    simulate,
    simulate_batched,
    simulate_grid,
)

N_PE = 1024

# the tracked perf-trajectory artifacts live at the repo root,
# independent of the benchmark's working directory
_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_ADMISSION_PATH = str(_ROOT / "BENCH_admission.json")
BENCH_SWEEP_PATH = str(_ROOT / "BENCH_sweep.json")


def _sweep(param_sets: List[Dict], n_jobs: int, seed: int
           ) -> List[Dict]:
    rows = []
    for ps in param_sets:
        jobs = generate(WorkloadParams(n_jobs=n_jobs, seed=seed,
                                       **ps))
        for r in run_policies(jobs, N_PE, ALL_POLICIES):
            rows.append({**ps, "policy": r.policy,
                         "acceptance": round(r.acceptance_rate, 4),
                         "slowdown": round(r.avg_slowdown, 4),
                         "util": round(r.utilization, 4),
                         "sched_wall_s": round(r.wall_seconds, 3)})
    return rows


def umed_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 2-3: acceptance/slowdown vs UMed in {5..9}."""
    return _sweep([{"u_med": float(u)} for u in (5, 6, 7, 8, 9)],
                  n_jobs, seed)


def load_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 4-5: acceptance/slowdown vs arrival factor."""
    return _sweep(
        [{"arrival_factor": f} for f in (0.5, 0.75, 1.0, 1.25, 1.5)],
        n_jobs, seed)


def flex_sweep(n_jobs: int = 2000, seed: int = 0) -> List[Dict]:
    """Figures 6-7: acceptance/slowdown vs {artime, deadline} factor."""
    return _sweep(
        [{"artime_factor": float(f), "deadline_factor": float(f)}
         for f in (1, 2, 3, 4, 5)],
        n_jobs, seed)


def admission_throughput(n_jobs: int = 240, n_pe: int = 64,
                         seed: int = 0,
                         out_path: Optional[str] = BENCH_ADMISSION_PATH
                         ) -> List[Dict]:
    """Admissions/sec: per-request loops vs the scanned device path.

    Three variants over the same workload and all seven policies: the
    host numpy loop, the per-request device loop (one host round-trip
    per job), and the fused ``admit_stream`` scan (DESIGN.md §3).  Each
    variant runs twice and the steady-state (second) run is reported so
    jit compilation does not distort the trajectory; results land in
    ``out_path`` for future PRs to compare against.
    """
    jobs = generate(WorkloadParams(n_jobs=n_jobs, n_pe=n_pe, seed=seed,
                                   u_low=2.0, u_med=4.0, u_hi=6.0))
    jobs = [j for j in jobs if j.n_pe <= n_pe]
    rows: List[Dict] = []
    for pol in ALL_POLICIES:
        variants = {
            "host_loop": lambda p=pol: simulate(
                jobs, n_pe, p, engine="host"),
            "device_loop": lambda p=pol: simulate(
                jobs, n_pe, p, engine="device",
                engine_kwargs={"capacity": 128}),
            "device_stream": lambda p=pol: simulate_batched(
                jobs, n_pe, p, capacity=128),
        }
        row: Dict = {"policy": pol.value}
        for name, fn in variants.items():
            fn()                      # warm-up: jit caches, buckets
            res = fn()                # steady state
            row[f"{name}_adm_per_s"] = round(
                len(jobs) / max(res.wall_seconds, 1e-9), 1)
            if name == "device_stream":
                row["acceptance"] = round(res.acceptance_rate, 4)
        row["stream_speedup_vs_device_loop"] = round(
            row["device_stream_adm_per_s"]
            / max(row["device_loop_adm_per_s"], 1e-9), 1)
        rows.append(row)
    if out_path:
        payload = {
            "bench": "admission_throughput",
            "n_jobs": len(jobs), "n_pe": n_pe, "seed": seed,
            "note": ("admissions/sec, steady state (second run); wall "
                     "time counts scheduler work only"),
            "rows": rows,
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    return rows


def sweep_throughput(n_jobs: int = 120, n_pe: int = 64,
                     out_path: Optional[str] = BENCH_SWEEP_PATH
                     ) -> List[Dict]:
    """Grid cells/sec: host loop vs per-cell scan vs vmapped grid.

    One Section-6 experiment matrix (7 policies × 3 loads × 3 seeds =
    63 cells, workloads shared across policies) evaluated three ways:

    * ``host_loop`` — the classic per-cell host event loop;
    * ``device_scan`` — one ``admit_stream`` scan per cell, cells
      dispatched sequentially from the host;
    * ``vmapped_grid`` — all cells as lanes of one vmapped scan
      (``simulate_grid``, DESIGN.md §4).

    Each variant runs twice and the steady-state (second) run is
    reported; wall time counts scheduler/dispatch work only.
    """
    from repro.sim.workload import generate_filtered

    spec = GridSpec(
        policies=ALL_POLICIES, arrival_factors=(1.0, 1.5, 2.0),
        seeds=(0, 1, 2), flex_factors=(3.0,),
        base=WorkloadParams(u_low=2.0, u_med=4.0, u_hi=6.0),
        n_pe=n_pe, n_jobs=n_jobs)
    workloads = {
        (lo, se, fl): generate_filtered(
            spec.workload_params(lo, se, fl), max_pe=n_pe)
        for lo, se, fl in itertools.product(
            spec.arrival_factors, spec.seeds, spec.flex_factors)}
    cells = [(pol, key) for pol in spec.policies for key in workloads]

    def host_loop() -> float:
        return sum(
            simulate(workloads[key], n_pe, pol,
                     engine="host").wall_seconds
            for pol, key in cells)

    def device_scan() -> float:
        return sum(
            simulate_batched(workloads[key], n_pe, pol,
                             capacity=128).wall_seconds
            for pol, key in cells)

    def vmapped_grid() -> float:
        return simulate_grid(spec, capacity=128).wall_seconds

    rows: List[Dict] = []
    walls: Dict[str, float] = {}
    for name, fn in (("host_loop", host_loop),
                     ("device_scan", device_scan),
                     ("vmapped_grid", vmapped_grid)):
        fn()                              # warm-up: jit caches
        wall = fn()                       # steady state
        walls[name] = wall
        rows.append({
            "variant": name,
            "n_cells": len(cells),
            "wall_s": round(wall, 4),
            "cells_per_s": round(len(cells) / max(wall, 1e-9), 2),
        })
    for row in rows:
        row["speedup_vs_host_loop"] = round(
            walls["host_loop"] / max(walls[row["variant"]], 1e-9), 2)
    if out_path:
        payload = {
            "bench": "sweep_throughput",
            "grid": {"policies": len(spec.policies),
                     "arrival_factors": list(spec.arrival_factors),
                     "seeds": list(spec.seeds),
                     "flex_factors": list(spec.flex_factors),
                     "n_jobs": n_jobs, "n_pe": n_pe},
            "note": ("Section-6 grid cells/sec, steady state (second "
                     "run); wall time counts scheduler/dispatch work "
                     "only"),
            "rows": rows,
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    return rows
