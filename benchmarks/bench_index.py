"""Hierarchical availability index: on-vs-off throughput (DESIGN.md §12).

Two cells, both asserting bit-identical decisions between the indexed
and index-free streams (the index only ever *prunes work*, never
changes an answer):

* ``standard`` — the admission-throughput workload per policy; the
  index pays its maintenance (tile re-summarise per update) against
  modest early-reject savings, so the gate here is a *floor*: no
  policy may fall below ``FLOOR_STANDARD`` of the index-free stream.
* ``saturated`` — a rejection-heavy advance-reservation stream: a
  fill phase packs overlapping reservations over a far-future horizon
  (staggered starts keep every boundary row distinct, so tile
  summaries stay informative), then a probe phase demands more PEs
  than any busy row has free with deadlines inside the horizon.
  Every probe is provably infeasible; ``summary_reject`` proves it
  from ``index_tile`` tile maxima and skips the whole candidate
  enumeration, which is the dominant cost at grown capacities.  Gate:
  at least ``FLOOR_SATURATED`` speedup over the index-free stream.
"""
from __future__ import annotations

import json
import pathlib
import statistics
from typing import Dict, List, Optional

from repro.core.types import ALL_POLICIES, ARRequest, Policy
from repro.sim import WorkloadParams, generate, simulate_batched

_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_INDEX_PATH = str(_ROOT / "BENCH_index.json")

# --check floors (ratios of warmed medians, index-on / index-off)
FLOOR_STANDARD = 0.95
FLOOR_SATURATED = 1.5


def saturated_jobs(n_fill: int = 240, n_probe: int = 480,
                   n_pe: int = 64) -> List[ARRequest]:
    """Fill-then-reject AR stream (all arrivals precede every start).

    Fill jobs reserve ``[1000 + 2k, 1004 + 2k)`` with 20..31 PEs —
    duration 4 over stride 2 means at most two overlap (<= 62 of 64
    PEs), so every fill job is admitted, and the varying widths keep
    consecutive boundary rows distinct (identical neighbours would
    merge away and leave tiles with a free row the reject bound
    cannot use).  Probes then ask for 48 PEs inside the horizon with
    zero slack: no busy row has 48 free, so all are rejected — the
    index-free stream discovers that by enumerating ~2S candidates,
    the indexed stream by one pass over the tile maxima.
    """
    jobs = []
    t = 0
    for k in range(n_fill):
        t_r = 1000 + 2 * k
        jobs.append(ARRequest(t_a=t, t_r=t_r, t_du=4, t_dl=t_r + 4,
                              n_pe=20 + (k % 12)))
        t += 1
    span = max(2 * n_fill - 200, 100)
    for k in range(n_probe):
        t_r = 1100 + (k * 7) % span
        jobs.append(ARRequest(t_a=t, t_r=t_r, t_du=8, t_dl=t_r + 8,
                              n_pe=48))
        t += 1
    return jobs


def _ab_medians(jobs, n_pe: int, policy: Policy, capacity: int,
                tile: int, repeats: int) -> Dict:
    """Interleaved A/B warmed medians + decision-parity assert.

    Off/on runs interleave *and* the within-pair order alternates:
    runner speed drifts monotonically over a process's life (cache
    and allocator state, frequency scaling), so a fixed off-first
    order would systematically flatter whichever side runs earlier in
    each pair.  The first (warmup) pair also checks the decisions
    match.
    """
    off = simulate_batched(jobs, n_pe, policy, capacity=capacity,
                           index_tile=None)
    on = simulate_batched(jobs, n_pe, policy, capacity=capacity,
                          index_tile=tile)
    assert off.decisions == on.decisions, (
        f"index changed decisions for {policy.value}")

    def _off():
        return simulate_batched(jobs, n_pe, policy, capacity=capacity,
                                index_tile=None).wall_seconds

    def _on():
        return simulate_batched(jobs, n_pe, policy, capacity=capacity,
                                index_tile=tile).wall_seconds

    offs, ons = [], []
    for i in range(max(repeats, 1)):
        if i % 2 == 0:
            offs.append(_off())
            ons.append(_on())
        else:
            ons.append(_on())
            offs.append(_off())
    w_off = statistics.median(offs)
    w_on = statistics.median(ons)
    n = len(jobs)
    return {
        "off_adm_per_s": round(n / max(w_off, 1e-9), 1),
        "on_adm_per_s": round(n / max(w_on, 1e-9), 1),
        "ratio_on_vs_off": round(w_off / max(w_on, 1e-9), 3),
        "acceptance": round(on.n_accepted / max(n, 1), 4),
    }


def index_throughput(n_jobs: int = 240, n_pe: int = 64, seed: int = 0,
                     capacity: int = 32, tile: int = 16,
                     sat_capacity: int = 256, sat_tile: int = 32,
                     repeats: int = 10,
                     out_path: Optional[str] = BENCH_INDEX_PATH
                     ) -> List[Dict]:
    """Index-on vs index-off admissions/sec, standard + saturated."""
    std = [j for j in generate(WorkloadParams(
        n_jobs=n_jobs, n_pe=n_pe, seed=seed,
        u_low=2.0, u_med=4.0, u_hi=6.0)) if j.n_pe <= n_pe]
    rows: List[Dict] = []
    for pol in ALL_POLICIES:
        rows.append({
            "cell": "standard", "policy": pol.value,
            "index_tile": tile,
            **_ab_medians(std, n_pe, pol, capacity, tile, repeats),
            "floor": FLOOR_STANDARD,
        })
    sat = saturated_jobs(n_pe=n_pe)
    rows.append({
        "cell": "saturated", "policy": Policy.FF.value,
        "index_tile": sat_tile,
        **_ab_medians(sat, n_pe, Policy.FF, sat_capacity, sat_tile,
                      repeats),
        "floor": FLOOR_SATURATED,
    })
    if out_path:
        payload = {
            "bench": "index_throughput",
            "n_jobs": n_jobs, "n_pe": n_pe, "seed": seed,
            "capacity": capacity, "tile": tile,
            "sat_capacity": sat_capacity, "sat_tile": sat_tile,
            "repeats": repeats,
            "note": ("hierarchical availability index on/off "
                     "(DESIGN.md §12); interleaved warmed medians of "
                     f"{repeats} A/B pairs with alternating "
                     "within-pair order (cancels monotone runner "
                     "drift); decisions asserted bit-identical each "
                     "cell; ratio_on_vs_off gates: standard >= "
                     f"{FLOOR_STANDARD} per policy, saturated >= "
                     f"{FLOOR_SATURATED}"),
            "rows": rows,
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    return rows
