"""Batched fleet ingress vs sequential probe-commit (DESIGN.md §9).

One section into ``BENCH_fleet.json``:

* ``fleet_routing`` — the same contended request stream admitted into
  a fresh E-partition fleet two ways.  ``batched`` is the PR 7 ingress
  (:meth:`PartitionedCore.admit_stream_allocations` with
  ``best_acceptance``): bounded probe → match → grouped-commit rounds,
  a constant number of device dispatches for the whole batch.
  ``sequential`` is the pre-PR 7 shape — one ``find_allocation`` probe
  plus one ``add_allocation`` commit per request, O(N) blocking
  round-trips.  Decisions are asserted bit-identical; rows carry warm
  requests/sec and the measured dispatch counts, and the section
  asserts the complexity claim directly: batched dispatches stay under
  the round bound while sequential dispatches scale with N.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks._measure import median_wall
from repro.core import ARRequest, Policy
from repro.core import ensemble as ens_lib
from repro.runtime.fleet import PartitionedCore

_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_FLEET_PATH = str(_ROOT / "BENCH_fleet.json")


def _gen(n: int, seed: int, spacing: int = 12, dmin: int = 50,
         dmax: int = 500, slack: float = 0.8, wmax: int = 30,
         pemax: int = 17) -> List[ARRequest]:
    """Contended arrival stream (PE widths up to one partition)."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0
    for _ in range(n):
        t += int(rng.integers(0, spacing))
        dur = int(rng.integers(dmin, dmax))
        r = t + int(rng.integers(0, wmax))
        t_dl = r + int(dur * (1.0 + slack * rng.random()))
        reqs.append(ARRequest(t_a=t, t_r=r, t_du=dur, t_dl=t_dl,
                              n_pe=int(rng.integers(1, pemax))))
    return reqs


def _key(a):
    return None if a is None else (a.t_s, a.t_e, tuple(a.pe_ids))


def fleet_routing(n_req: int = 128, n_chips: int = 64,
                  n_parts: int = 4, capacity: int = 256,
                  seed: int = 7, repeats: int = 5,
                  out_path: Optional[str] = BENCH_FLEET_PATH
                  ) -> List[Dict]:
    """Requests/sec of batched vs sequential best-acceptance ingress.

    Every run starts from a fresh fleet (ingress is a cold-timeline
    operation); the first run per variant is the jit warmup.  The
    batched matcher must admit the whole batch in at most
    ``3 * match_max_rounds + 1`` dispatches (probe + match + grouped
    commit per round, one fused tail) regardless of ``n_req``; the
    sequential loop pays at least one probe dispatch per request.
    """
    reqs = _gen(n_req, seed=seed)
    policy = Policy.FF

    def run_batched() -> float:
        core = PartitionedCore(n_chips, n_parts, capacity=capacity)
        t0 = time.perf_counter()
        allocs = core.admit_stream_allocations(
            reqs, policy, routing="best_acceptance")
        wall = time.perf_counter() - t0
        run_batched.allocs = allocs
        run_batched.dispatches = core.dispatches
        run_batched.rounds = core.last_match_rounds
        return wall

    def run_sequential() -> float:
        core = PartitionedCore(n_chips, n_parts, capacity=capacity)
        t0 = time.perf_counter()
        allocs = []
        for r in reqs:
            a = core.find_allocation(r, policy)
            if a is not None:
                core.add_allocation(a.t_s, a.t_e, a.pe_ids)
            allocs.append(a)
        wall = time.perf_counter() - t0
        run_sequential.allocs = allocs
        run_sequential.dispatches = core.dispatches
        run_sequential.rounds = 0
        return wall

    rows: List[Dict] = []
    walls: Dict[str, float] = {}
    for variant, run in (("batched", run_batched),
                         ("sequential", run_sequential)):
        run()                                    # compile + warm
        steady0 = ens_lib.match_stream_ensemble._cache_size()
        wall = median_wall(run, repeats)
        steady_recompiles = (
            ens_lib.match_stream_ensemble._cache_size() - steady0)
        walls[variant] = wall
        rows.append({
            "variant": variant,
            "n_requests": n_req,
            "n_partitions": n_parts,
            "accepted": sum(a is not None for a in run.allocs),
            "warm_wall_s": round(wall, 4),
            "warm_req_per_s": round(n_req / max(wall, 1e-9), 1),
            "dispatches": run.dispatches,
            "match_rounds": run.rounds,
            "steady_recompiles": steady_recompiles,
        })
    by = {r["variant"]: r for r in rows}
    assert ([_key(a) for a in run_batched.allocs]
            == [_key(a) for a in run_sequential.allocs]), \
        "batched matcher diverged from sequential probe-commit"
    bound = 3 * PartitionedCore.match_max_rounds + 1
    assert by["batched"]["dispatches"] <= bound, \
        f"batched ingress is not constant-dispatch: " \
        f"{by['batched']['dispatches']} > {bound}"
    assert by["sequential"]["dispatches"] >= n_req, \
        "sequential baseline lost its per-request probe dispatches"
    assert by["batched"]["steady_recompiles"] == 0, \
        "warmed batched ingress recompiled the fused matcher"
    for row in rows:
        row["decisions_bit_identical"] = True
        row["speedup_vs_sequential"] = round(
            walls["sequential"] / max(walls[row["variant"]], 1e-9), 2)
    if out_path:
        payload = {
            "bench": "fleet",
            "fleet_routing": {
                "n_requests": n_req, "n_chips": n_chips,
                "n_partitions": n_parts, "capacity": capacity,
                "seed": seed, "repeats": repeats,
                "dispatch_bound": bound,
                "note": ("same stream, fresh fleet per run, "
                         "warmed-up median walls; batched = bounded "
                         "probe/match/grouped-commit rounds (PR 7), "
                         "sequential = per-request probe+commit; "
                         "decisions asserted bit-identical; batched "
                         "dispatches must stay under the round bound "
                         "while sequential scales with N"),
                "rows": rows,
            },
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    return rows
