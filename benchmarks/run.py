"""Benchmark harness: one section per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
    PYTHONPATH=src python -m benchmarks.run --list
    PYTHONPATH=src python -m benchmarks.run --check [--tolerance T]
    PYTHONPATH=src python -m benchmarks.run --profile [--profile-dir D]

Prints CSV blocks: ``name,...columns`` per section.  ``--full`` uses
the paper's 10^4-job workloads (slow); default is a reduced size that
preserves every reported ordering.

``--check`` is the perf-regression mode (CI ``perf-smoke``): it
re-measures the eight BENCH benchmarks at reduced sizes and compares
the freshly measured *ratios* — device-vs-host throughput, backfill
mode cost vs the plain scan, ring-vs-rescan streaming,
sharded-vs-single mesh placement, pipelined-vs-eager chunked offers,
batched-vs-sequential fleet ingress, tenancy-on-vs-off gated
admission (plus the hard zero on idle metrics-poll device fetches)
and the multi-resource timeline cost curve (R=1 parity overhead and
the R=4 plane cost vs the legacy single-plane session) — against the
committed
``BENCH_*.json`` files with a tolerance band, plus the hierarchical-
index floors: per-policy machine-normalised ``speedup_vs_pr5 >= 1.0``
and the index on-vs-off ratios (standard-stream floor, saturated
early-reject speedup, BENCH_index.json).  Ratios only:
absolute wall times are meaningless on shared runners, but a device
path that regresses from 3x-faster-than-host to slower-than-host
moves its ratio far beyond any plausible machine noise.

``--profile`` writes a ``jax.profiler`` trace (one warmed
``admit_stream`` + one vmapped sweep-grid dispatch) to
``--profile-dir`` for the CI artifact upload.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _emit(name: str, rows) -> None:
    print(f"\n== {name} ==")
    if not rows:
        print("(no rows)")
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
    sys.stdout.flush()


def _committed(name: str) -> dict:
    path = _ROOT / f"BENCH_{name}.json"
    with open(path) as fh:
        return json.load(fh)


def check(tolerance: float) -> int:
    """Ratio gates vs the committed BENCH files; returns #failures.

    Fresh measurements use the committed workload sizes with fewer
    repeats; ``tolerance`` is the allowed *relative* drift of each
    ratio (default 0.5: a committed 3.0x device-vs-host gate fails
    below 1.5x).  Cost-ratio ("le") gates get an extra +0.5 absolute
    slack — their committed values sit near 1.0, where relative bands
    are tighter than shared-runner noise on tens-of-ms walls.  No
    absolute wall-time asserts anywhere.
    """
    from benchmarks import bench_backfill, bench_fleet, bench_index, \
        bench_mesh, bench_multires, bench_policies, bench_service, \
        bench_tenancy

    failures = []
    checks = []

    def gate(label: str, fresh: float, committed: float,
             direction: str) -> None:
        if direction == "ge":
            bound = committed * (1.0 - tolerance)
            ok = fresh >= bound
        else:
            bound = committed * (1.0 + tolerance) + 0.5
            ok = fresh <= bound
        checks.append({
            "gate": label, "fresh_ratio": round(fresh, 3),
            "committed_ratio": round(committed, 3),
            "bound": round(bound, 3),
            "direction": direction,
            "status": "PASS" if ok else "FAIL",
        })
        if not ok:
            failures.append(label)

    # -- admission: device stream vs host loop ------------------------
    # one gate on the MEDIAN ratio across the seven policies: the
    # per-policy ratios move several 10s of percent with the host
    # loop's cache behaviour on shared runners, the median is stable
    from benchmarks._measure import median

    ref_rows = _committed("admission")["rows"]
    rows = bench_policies.admission_throughput(repeats=3,
                                               out_path=None)
    fresh = median(
        r["device_stream_adm_per_s"] / max(
            r["host_loop_adm_per_s"], 1e-9) for r in rows)
    committed = median(
        r["device_stream_adm_per_s"] / max(
            r["host_loop_adm_per_s"], 1e-9) for r in ref_rows)
    gate("admission/median:stream_vs_host", fresh, committed, "ge")

    # -- admission: per-policy machine-normalised PR 5 floor ----------
    # the PR 5 regression rows must stay recovered: every freshly
    # measured speedup_vs_pr5 (host-geomean normalised, so runner
    # speed cancels) holds the 1.0 floor
    for r in rows:
        gate(f"admission/{r['policy']}:speedup_vs_pr5",
             r["speedup_vs_pr5"], 1.0, "ge")

    # -- index: on-vs-off ratio floors (BENCH_index.json) -------------
    # standard stream may not dip below the per-policy floor; the
    # saturated early-reject cell must keep its speedup.  Both are
    # same-machine A/B ratios, immune to runner speed.
    idx_rows = bench_index.index_throughput(repeats=3, out_path=None)
    for r in idx_rows:
        label = (f"index/{r['policy']}:on_vs_off"
                 if r["cell"] == "standard"
                 else "index/saturated:on_vs_off")
        gate(label, r["ratio_on_vs_off"], r["floor"], "ge")

    # -- sweep: vmapped grid vs host loop -----------------------------
    ref = {r["variant"]: r for r in _committed("sweep")["rows"]}
    rows = bench_policies.sweep_throughput(repeats=3, out_path=None)
    got = {r["variant"]: r for r in rows}
    for variant in ("device_scan", "vmapped_grid"):
        fresh = got[variant]["cells_per_s"] / max(
            got["host_loop"]["cells_per_s"], 1e-9)
        committed = ref[variant]["cells_per_s"] / max(
            ref["host_loop"]["cells_per_s"], 1e-9)
        gate(f"sweep/{variant}:vs_host", fresh, committed, "ge")

    # -- backfill: mode cost vs the plain scan ------------------------
    ref = {r["mode"]: r for r in _committed("backfill")["rows"]}
    rows = bench_backfill.backfill_throughput(repeats=5,
                                              out_path=None)
    for row in rows:
        mode = row["mode"]
        if mode in ("none", "none_idle") or mode not in ref:
            continue
        gate(f"backfill/{mode}:cost_vs_plain",
             row["warm_cost_vs_plain"],
             ref[mode]["warm_cost_vs_plain"], "le")

    # -- service: warm ring-chunked vs re-scan ------------------------
    ref = {r["variant"]: r for r in _committed("service")["rows"]}
    rows = bench_service.service_throughput(repeats=3, out_path=None)
    got = {r["variant"]: r for r in rows}
    fresh = got["ring_chunked"]["warm_req_per_s"] / max(
        got["rescan_per_group"]["warm_req_per_s"], 1e-9)
    committed = ref["ring_chunked"]["warm_req_per_s"] / max(
        ref["rescan_per_group"]["warm_req_per_s"], 1e-9)
    gate("service/ring_vs_rescan:warm", fresh, committed, "ge")

    # -- tenancy: gated step cost vs the zero-tenant session ----------
    # the zero-tenant path must stay at the PR 7 ring-chunked cost
    # (ratio vs the freshly measured service bench ~ the committed
    # one), the tenanted path within its committed constant factor,
    # and idle metrics polls must stay fetch-free (hard 0 gate)
    ten_ref = {r["variant"]: r for r in _committed("tenancy")["rows"]}
    ten_got = {r["variant"]: r for r in bench_tenancy.
               tenancy_throughput(repeats=3, out_path=None)}
    service_ref = ref
    fresh = ten_got["tenancy_off"]["warm_req_per_s"] / max(
        got["ring_chunked"]["warm_req_per_s"], 1e-9)
    committed = ten_ref["tenancy_off"]["warm_req_per_s"] / max(
        service_ref["ring_chunked"]["warm_req_per_s"], 1e-9)
    gate("tenancy/off_vs_pr7_ring:warm", fresh, committed, "ge")
    gate("tenancy/on_vs_off:cost",
         ten_got["tenancy_on"]["cost_vs_off"],
         ten_ref["tenancy_on"]["cost_vs_off"], "le")
    gate("tenancy/idle_poll:device_fetches",
         float(ten_got["metrics_poll"]["idle_device_fetches"]),
         float(ten_ref["metrics_poll"]["idle_device_fetches"]), "le")

    # -- multires: plane-count cost vs the legacy single-plane path ---
    # both gates are cost ratios against the SAME freshly measured
    # legacy stream, so machine speed cancels: r1 prices the rspec
    # code path on a byte-identical layout, r4 pins the plane cost
    # curve (a superlinear regression blows far past the band)
    mr_ref = {r["variant"]: r for r in _committed("multires")["rows"]}
    mr_got = {r["variant"]: r for r in bench_multires.
              multires_throughput(repeats=3, out_path=None)}
    for variant in ("r1", "r4"):
        gate(f"multires/{variant}_vs_legacy:cost",
             mr_got[variant]["cost_vs_legacy"],
             mr_ref[variant]["cost_vs_legacy"], "le")

    # -- mesh: sharded grid vs single placement, pipelined vs eager ---
    # a reduced 168-lane grid keeps the CI lane fast; both gates are
    # ratios of same-machine variants, so the size reduction cancels
    mesh_doc = _committed("mesh")
    ref = {r["variant"]: r
           for r in mesh_doc["sharded_grid"]["rows"]}
    got = {r["variant"]: r for r in bench_mesh.sharded_grid(
        n_seeds=8, repeats=3, out_path=None)}
    fresh = got["sharded_auto"]["cells_per_s"] / max(
        got["single_device"]["cells_per_s"], 1e-9)
    committed = ref["sharded_auto"]["cells_per_s"] / max(
        ref["single_device"]["cells_per_s"], 1e-9)
    gate("mesh/sharded_grid:vs_single", fresh, committed, "ge")
    gate("mesh/sharded_grid:steady_recompiles",
         float(got["sharded_auto"]["steady_recompiles"]),
         float(ref["sharded_auto"]["steady_recompiles"]), "le")

    ref = {r["variant"]: r
           for r in mesh_doc["offer_overlap"]["rows"]}
    got = {r["variant"]: r for r in bench_mesh.offer_overlap(
        repeats=3, out_path=None)}
    fresh = got["pipelined"]["warm_req_per_s"] / max(
        got["eager"]["warm_req_per_s"], 1e-9)
    committed = ref["pipelined"]["warm_req_per_s"] / max(
        ref["eager"]["warm_req_per_s"], 1e-9)
    gate("mesh/offer_overlap:pipelined_vs_eager", fresh, committed,
         "ge")

    # -- fleet: batched matcher vs sequential probe-commit ------------
    ref = {r["variant"]: r
           for r in _committed("fleet")["fleet_routing"]["rows"]}
    got = {r["variant"]: r for r in bench_fleet.fleet_routing(
        repeats=3, out_path=None)}
    fresh = got["batched"]["warm_req_per_s"] / max(
        got["sequential"]["warm_req_per_s"], 1e-9)
    committed = ref["batched"]["warm_req_per_s"] / max(
        ref["sequential"]["warm_req_per_s"], 1e-9)
    gate("fleet/batched_vs_sequential:warm", fresh, committed, "ge")
    gate("fleet/batched:dispatches",
         float(got["batched"]["dispatches"]),
         float(ref["batched"]["dispatches"]), "le")

    _emit("perf_check", checks)
    if failures:
        print(f"\n# PERF CHECK FAILED: {len(failures)} gate(s) out of "
              f"band (tolerance {tolerance}): {failures}")
    else:
        print(f"\n# perf check OK: {len(checks)} ratio gates within "
              f"tolerance {tolerance}")
    return len(failures)


def profile(outdir: str) -> None:
    """Capture a ``jax.profiler`` trace of the two hot dispatch paths.

    One warmed ``admit_stream`` scan (the standard admission workload,
    index on) and one warmed vmapped sweep-grid dispatch — both run
    once outside the trace so compilation and the grow-once overflow
    protocol settle, then once inside it.  The trace directory is the
    CI ``perf-profile`` artifact; open it with any Perfetto/
    TensorBoard trace viewer.
    """
    import jax

    from repro.core.types import ALL_POLICIES, Policy
    from repro.sim import (GridSpec, WorkloadParams, generate,
                           simulate_batched, simulate_grid)

    jobs = [j for j in generate(WorkloadParams(
        n_jobs=240, n_pe=64, seed=0,
        u_low=2.0, u_med=4.0, u_hi=6.0)) if j.n_pe <= 64]
    spec = GridSpec(
        policies=ALL_POLICIES, arrival_factors=(1.0,), seeds=(0,),
        flex_factors=(3.0,),
        base=WorkloadParams(u_low=2.0, u_med=4.0, u_hi=6.0),
        n_pe=64, n_jobs=120)
    # warm: compile + grow to steady-state shapes
    simulate_batched(jobs, 64, Policy.PE_W, capacity=32, index_tile=16)
    simulate_grid(spec, capacity=32)
    with jax.profiler.trace(outdir):
        simulate_batched(jobs, 64, Policy.PE_W, capacity=32,
                         index_tile=16)
        simulate_grid(spec, capacity=32)
    print(f"# profiler trace written to {outdir}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale 10^4-job sweeps")
    ap.add_argument("--only", default=None)
    ap.add_argument("--list", action="store_true",
                    help="print every section name and exit")
    ap.add_argument("--check", action="store_true",
                    help="ratio-gate regression mode vs BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed relative ratio drift in --check")
    ap.add_argument("--profile", action="store_true",
                    help="write a jax.profiler trace of one warmed "
                         "admit_stream + sweep-grid dispatch")
    ap.add_argument("--profile-dir", default="artifacts/profile",
                    help="trace output directory for --profile")
    args = ap.parse_args()
    if args.check:
        sys.exit(1 if check(args.tolerance) else 0)
    if args.profile:
        profile(args.profile_dir)
        return
    n_jobs = 10_000 if args.full else 2_000
    t0 = time.time()

    from benchmarks import bench_backfill, bench_datastructure, \
        bench_fleet, bench_index, bench_mesh, bench_multires, \
        bench_policies, bench_service, bench_tenancy, gen_experiments
    from benchmarks.bench_roofline import ART_OPT, roofline_rows

    sections = {
        "fig2_3_umed_sweep":
            lambda: bench_policies.umed_sweep(n_jobs=n_jobs),
        "fig4_5_load_sweep":
            lambda: bench_policies.load_sweep(n_jobs=n_jobs),
        "fig6_7_flex_sweep":
            lambda: bench_policies.flex_sweep(n_jobs=n_jobs),
        "admission_throughput":
            lambda: bench_policies.admission_throughput(
                n_jobs=600 if args.full else 240),
        "sweep_throughput":
            lambda: bench_policies.sweep_throughput(
                n_jobs=300 if args.full else 120),
        "service_throughput":
            lambda: bench_service.service_throughput(
                n_jobs=600 if args.full else 240),
        "backfill_throughput":
            lambda: bench_backfill.backfill_throughput(
                n_jobs=600 if args.full else 240),
        "tenancy_throughput":
            lambda: bench_tenancy.tenancy_throughput(
                n_jobs=600 if args.full else 240),
        "multires_throughput":
            lambda: bench_multires.multires_throughput(
                n_jobs=600 if args.full else 240),
        "mesh_sharded_grid":
            lambda: bench_mesh.sharded_grid(),
        "mesh_offer_overlap":
            lambda: bench_mesh.offer_overlap(
                n_jobs=600 if args.full else 240),
        "fleet_routing":
            lambda: bench_fleet.fleet_routing(
                n_req=256 if args.full else 128),
        "index_throughput":
            lambda: bench_index.index_throughput(
                n_jobs=600 if args.full else 240),
        "datastructure_op_costs":
            lambda: bench_datastructure.op_costs(
                n_jobs=800 if args.full else 300),
        "datastructure_pe_scaling":
            lambda: bench_datastructure.scaling_with_pe_count(
                n_jobs=400 if args.full else 200),
        "roofline_single_pod":
            lambda: roofline_rows("single"),
        "roofline_multi_pod":
            lambda: roofline_rows("multi"),
        "roofline_optimized_single_pod":
            lambda: roofline_rows("single", ART_OPT),
        "experiments_tables":
            lambda: gen_experiments.tables(),
    }
    if args.list:
        for name in sections:
            print(name)
        return
    for name, fn in sections.items():
        if args.only and args.only != name:
            continue
        t = time.time()
        _emit(name, fn())
        print(f"# {name}: {time.time()-t:.1f}s")
    print(f"\n# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
