"""Benchmark harness: one section per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints CSV blocks: ``name,...columns`` per section.  ``--full`` uses
the paper's 10^4-job workloads (slow); default is a reduced size that
preserves every reported ordering.
"""
from __future__ import annotations

import argparse
import sys
import time


def _emit(name: str, rows) -> None:
    print(f"\n== {name} ==")
    if not rows:
        print("(no rows)")
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
    sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale 10^4-job sweeps")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    n_jobs = 10_000 if args.full else 2_000
    t0 = time.time()

    from benchmarks import bench_backfill, bench_datastructure, \
        bench_policies, bench_service
    from benchmarks.bench_roofline import ART_OPT, roofline_rows

    sections = {
        "fig2_3_umed_sweep":
            lambda: bench_policies.umed_sweep(n_jobs=n_jobs),
        "fig4_5_load_sweep":
            lambda: bench_policies.load_sweep(n_jobs=n_jobs),
        "fig6_7_flex_sweep":
            lambda: bench_policies.flex_sweep(n_jobs=n_jobs),
        "admission_throughput":
            lambda: bench_policies.admission_throughput(
                n_jobs=600 if args.full else 240),
        "sweep_throughput":
            lambda: bench_policies.sweep_throughput(
                n_jobs=300 if args.full else 120),
        "service_throughput":
            lambda: bench_service.service_throughput(
                n_jobs=600 if args.full else 240),
        "backfill_throughput":
            lambda: bench_backfill.backfill_throughput(
                n_jobs=600 if args.full else 240),
        "datastructure_op_costs":
            lambda: bench_datastructure.op_costs(
                n_jobs=800 if args.full else 300),
        "datastructure_pe_scaling":
            lambda: bench_datastructure.scaling_with_pe_count(
                n_jobs=400 if args.full else 200),
        "roofline_single_pod":
            lambda: roofline_rows("single"),
        "roofline_multi_pod":
            lambda: roofline_rows("multi"),
        "roofline_optimized_single_pod":
            lambda: roofline_rows("single", ART_OPT),
    }
    for name, fn in sections.items():
        if args.only and args.only != name:
            continue
        t = time.time()
        _emit(name, fn())
        print(f"# {name}: {time.time()-t:.1f}s")
    print(f"\n# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
