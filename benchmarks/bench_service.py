"""Service streaming throughput: ring-chunked admission vs re-scan.

An online reservation service receives arrivals in irregular groups
and must answer each group before the next.  Pre-service, the only
batched path was ``admit_stream`` on an exactly-sized batch per group:
every distinct group length is a new scan shape, so the server
re-traces/recompiles continually and pays the re-pack on the host.
The session's ring-buffer path (`repro.api.Session.offer`) admits the
same groups through constant-shape chunks — one compile at warmup,
zero re-padding after.

Both variants make bit-identical decisions; the benchmark reports
requests/sec cold (first run, compiles included — the online-service
reality for the re-scan baseline) and warm (second run, all shapes
cached) into ``BENCH_service.json``.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional

import numpy as np

from repro.api import ReservationService, ServiceConfig
from repro.core import batch as batch_lib
from repro.core import timeline as tl_lib
from repro.core.types import Policy
from repro.sim import WorkloadParams, generate

_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_SERVICE_PATH = str(_ROOT / "BENCH_service.json")


def _arrival_groups(jobs, chunk: int, seed: int) -> List[list]:
    """Split the stream into irregular groups (1 .. 1.5 * chunk)."""
    rng = np.random.RandomState(seed)
    groups, i = [], 0
    while i < len(jobs):
        take = int(rng.randint(1, 3 * chunk // 2))
        groups.append(jobs[i:i + take])
        i += take
    return groups


def service_throughput(n_jobs: int = 240, n_pe: int = 64,
                       chunk: int = 64, seed: int = 0,
                       repeats: int = 5,
                       out_path: Optional[str] = BENCH_SERVICE_PATH
                       ) -> List[Dict]:
    """Requests/sec of the two online-admission strategies.

    * ``rescan_per_group`` — carried state + one exactly-sized
      ``admit_stream`` scan per arrival group (the pre-service online
      path): every distinct group length is a fresh jit shape.
    * ``ring_chunked`` — one service session; groups stage in the ring
      and admit as fixed-shape chunks (compiles once at warmup).

    Each variant answers every group (decision sync per group);
    ``cold`` includes compilation — the steady reality of the re-scan
    server, whose shapes keep changing — and ``warm`` is the median of
    ``repeats`` runs with every shape cached.  ``speedup_vs_pr4`` /
    ``speedup_vs_pr5`` compare warm requests/sec to the frozen
    prior-PR baselines (:mod:`benchmarks._measure`).
    """
    from benchmarks._measure import (
        PR4_SERVICE_WARM, PR5_ADMISSION_HOST, PR5_SERVICE_WARM,
        PR6_ADMISSION_HOST, PR6_SERVICE_WARM, PR9_ADMISSION_HOST,
        PR9_SERVICE_WARM, host_yardstick, median)

    jobs = sorted(
        [j for j in generate(WorkloadParams(
            n_jobs=n_jobs, n_pe=n_pe, seed=seed,
            u_low=2.0, u_med=4.0, u_hi=6.0)) if j.n_pe <= n_pe],
        key=lambda j: j.t_a)
    groups = _arrival_groups(jobs, chunk, seed)
    policy = Policy.PE_W

    def rescan_per_group() -> float:
        state = tl_lib.init_state(128, n_pe, 256)
        accepted = 0
        t0 = time.perf_counter()
        for g in groups:
            state, dec = batch_lib.admit_stream_grow(
                state, batch_lib.requests_to_batch(g), policy,
                n_pe=n_pe)
            accepted += int(np.asarray(dec.accepted).sum())
        wall = time.perf_counter() - t0
        rescan_per_group.accepted = accepted
        return wall

    def ring_chunked() -> float:
        sess = ReservationService(ServiceConfig(
            n_pe=n_pe, policy=policy, capacity=128,
            pending_capacity=256, chunk_size=chunk,
            ring_capacity=2 * chunk)).session()
        accepted = 0
        t0 = time.perf_counter()
        for g in groups:
            accepted += sess.offer(g).n_accepted
        wall = time.perf_counter() - t0
        ring_chunked.accepted = accepted
        return wall

    rows: List[Dict] = []
    walls: Dict[str, float] = {}
    for name, fn in (("rescan_per_group", rescan_per_group),
                     ("ring_chunked", ring_chunked)):
        cache0 = batch_lib.admit_stream._cache_size()
        cold = fn()
        compiles = batch_lib.admit_stream._cache_size() - cache0
        warm = median(fn() for _ in range(max(repeats, 1)))
        walls[name] = cold
        rows.append({
            "variant": name,
            "n_requests": len(jobs),
            "n_groups": len(groups),
            "scan_compiles": compiles,
            "cold_wall_s": round(cold, 4),
            "cold_req_per_s": round(len(jobs) / max(cold, 1e-9), 1),
            "warm_wall_s": round(warm, 4),
            "warm_req_per_s": round(len(jobs) / max(warm, 1e-9), 1),
            "accepted": fn.accepted,
        })
    # machine-normalised cross-PR speedups (see bench_backfill)
    yard = host_yardstick()
    eras = (("speedup_vs_pr4", PR4_SERVICE_WARM, PR5_ADMISSION_HOST),
            ("speedup_vs_pr5", PR5_SERVICE_WARM, PR5_ADMISSION_HOST),
            ("speedup_vs_pr6", PR6_SERVICE_WARM, PR6_ADMISSION_HOST),
            ("speedup_vs_pr9", PR9_SERVICE_WARM, PR9_ADMISSION_HOST))
    for row in rows:
        row["cold_speedup_vs_rescan"] = round(
            walls["rescan_per_group"] / max(
                walls[row["variant"]], 1e-9), 2)
        for col, warm, hosts in eras:
            m = yard / max(hosts["FF"], 1e-9)
            row[col] = round(
                row["warm_req_per_s"]
                / max(warm[row["variant"]] * m, 1e-9), 2)
    assert rows[0]["accepted"] == rows[1]["accepted"], \
        "streaming variants diverged"
    if out_path:
        payload = {
            "bench": "service_throughput",
            "n_jobs": len(jobs), "n_pe": n_pe, "chunk": chunk,
            "seed": seed, "repeats": repeats,
            "note": ("online admission in irregular arrival groups; "
                     "cold includes jit compiles (the re-scan server "
                     "keeps seeing new shapes), warm has all shapes "
                     "cached (warm = median of repeats); decisions "
                     "bit-identical across variants"),
            "rows": rows,
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    return rows
