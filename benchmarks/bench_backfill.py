"""Backfilling admission throughput: deferral-queue scan vs plain scan.

The backfill modes (DESIGN.md §6) widen every fused admission step:
promotion and the retry sweep loop over the deferral queue, and under
``vmap`` the EASY displacement transaction's searches execute for every
lane.  This benchmark quantifies that cost — decisions/sec of the
plain ``none`` scan (``park_capacity == 0``, the pre-backfill graph)
against the EASY and conservative scans on the same stream — plus the
acceptance each mode buys, into ``BENCH_backfill.json``.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import batch as batch_lib
from repro.core import timeline as tl_lib
from repro.core.types import Policy
from repro.sim import WorkloadParams, generate_filtered

_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_BACKFILL_PATH = str(_ROOT / "BENCH_backfill.json")


def backfill_throughput(n_jobs: int = 240, n_pe: int = 16,
                        park_capacity: int = 8, seed: int = 3,
                        out_path: Optional[str] = BENCH_BACKFILL_PATH
                        ) -> List[Dict]:
    """Decisions/sec of one-shot ``admit_stream`` per backfill mode.

    All variants admit the same arrival-ordered stream (a fragmented
    small machine, where EASY displacement has real holes to fill).
    ``cold`` includes compilation; ``warm`` re-runs with every shape
    cached.  The EASY/conservative rows share one jit entry (the mode
    is traced), so their cold walls differ only by compile order.
    """
    jobs = sorted(generate_filtered(WorkloadParams(
        n_jobs=n_jobs, n_pe=n_pe, seed=seed, arrival_factor=2.5,
        u_low=2.0, u_med=3.0, u_hi=4.0), max_pe=n_pe),
        key=lambda j: j.t_a)
    batch = batch_lib.requests_to_batch(jobs)
    policy = Policy.PE_W

    rows: List[Dict] = []
    walls: Dict[str, float] = {}
    for mode in ("none", "easy", "conservative"):
        q = 0 if mode == "none" else park_capacity

        def run() -> float:
            state = tl_lib.init_state(128, n_pe, 256,
                                      park_capacity=q)
            t0 = time.perf_counter()
            out, dec = batch_lib.admit_stream_grow(
                state, batch, policy, n_pe=n_pe, backfill=mode)
            n_acc = int(np.asarray(dec.accepted).sum())
            wall = time.perf_counter() - t0
            run.accepted = n_acc
            run.parked = int(out.n_parked)
            return wall

        cold = run()
        warm = run()
        walls[mode] = warm
        rows.append({
            "mode": mode,
            "park_capacity": q,
            "n_requests": len(jobs),
            "accepted": run.accepted,
            "parked": run.parked,
            "cold_wall_s": round(cold, 4),
            "warm_wall_s": round(warm, 4),
            "warm_decisions_per_s": round(
                len(jobs) / max(warm, 1e-9), 1),
        })
    for row in rows:
        row["warm_cost_vs_plain"] = round(
            walls[row["mode"]] / max(walls["none"], 1e-9), 2)
    assert rows[2]["accepted"] == rows[0]["accepted"], \
        "conservative must be decision-identical to none"
    assert rows[1]["accepted"] >= rows[0]["accepted"], \
        "EASY lost acceptance on the benchmark workload"
    if out_path:
        payload = {
            "bench": "backfill_throughput",
            "n_jobs": len(jobs), "n_pe": n_pe,
            "park_capacity": park_capacity, "seed": seed,
            "note": ("one-shot admit_stream per backfill mode on a "
                     "shared fragmented-machine stream; conservative "
                     "is decision-identical to none, EASY trades "
                     "per-step deferral-queue compute for strictly "
                     "higher acceptance"),
            "rows": rows,
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    return rows
