"""Backfilling admission throughput: deferral-queue scan vs plain scan.

The backfill modes (DESIGN.md §6) widen every fused admission step:
promotion and the retry sweep loop over the deferral queue, and under
``vmap`` the EASY displacement transaction's searches execute for every
lane.  This benchmark quantifies that cost — decisions/sec of the
plain ``none`` scan (``park_capacity == 0``, the pre-backfill graph)
against the EASY and conservative scans on the same stream — plus the
acceptance each mode buys, into ``BENCH_backfill.json``.

PR 5 (DESIGN.md §7) cond-gated the parked machinery on live-queue
predicates, so a step whose queue is idle compiles to (and pays)
mode-``none`` cost: the ``easy_idle`` row pins that in data by running
EASY on a light stream where nothing ever parks (asserted) and
reporting its cost against ``none`` on the same stream.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks._measure import (
    PR4_BACKFILL_COST,
    PR4_BACKFILL_DPS,
    PR5_ADMISSION_HOST,
    PR5_BACKFILL_COST,
    PR5_BACKFILL_DPS,
    PR6_ADMISSION_HOST,
    PR6_BACKFILL_COST,
    PR6_BACKFILL_DPS,
    PR9_ADMISSION_HOST,
    PR9_BACKFILL_COST,
    PR9_BACKFILL_DPS,
    host_yardstick,
    median,
)
from repro.core import batch as batch_lib
from repro.core import timeline as tl_lib
from repro.core.types import Policy
from repro.sim import WorkloadParams, generate_filtered

_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_BACKFILL_PATH = str(_ROOT / "BENCH_backfill.json")


def _stream(n_jobs: int, n_pe: int, seed: int, load: float):
    return sorted(generate_filtered(WorkloadParams(
        n_jobs=n_jobs, n_pe=n_pe, seed=seed, arrival_factor=load,
        u_low=2.0, u_med=3.0, u_hi=4.0), max_pe=n_pe),
        key=lambda j: j.t_a)


def _idle_stream(n_jobs: int, n_pe: int, seed: int):
    """A stream whose deferral queue provably stays empty.

    Arrivals are spaced wider than any duration, so at most one
    reservation is ever live and every accept starts at its ready
    time — nothing can park (``t_s == t_r``), which is exactly the
    cond-gating scenario the ``easy_idle`` row measures.
    """
    from repro.core.types import ARRequest

    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(n_jobs):
        t = 50 * i
        du = int(rng.integers(5, 31))
        jobs.append(ARRequest(
            t_a=t, t_r=t, t_du=du, t_dl=t + du + int(rng.integers(0, 20)),
            n_pe=int(rng.integers(1, n_pe + 1))))
    return jobs


def backfill_throughput(n_jobs: int = 240, n_pe: int = 16,
                        park_capacity: int = 8, seed: int = 3,
                        capacity: int = 128, repeats: int = 9,
                        out_path: Optional[str] = BENCH_BACKFILL_PATH
                        ) -> List[Dict]:
    """Decisions/sec of one-shot ``admit_stream`` per backfill mode.

    The classic rows admit one arrival-ordered stream (a fragmented
    small machine, where EASY displacement has real holes to fill);
    the ``*_idle`` rows admit a light stream on the same machine where
    every accept starts at its ready time, so the deferral queue stays
    empty for the whole run — the cond-gating scenario.  ``cold``
    includes compilation; ``warm`` is the median of ``repeats`` warmed
    runs.  The EASY/conservative rows share one jit entry (the mode is
    traced), so their cold walls differ only by compile order.
    """
    busy = _stream(n_jobs, n_pe, seed, load=2.5)
    idle = _idle_stream(n_jobs, n_pe, seed + 1)
    policy = Policy.PE_W

    def make_run(jobs, mode: str, q: int):
        batch = batch_lib.requests_to_batch(jobs)

        def run() -> float:
            state = tl_lib.init_state(capacity, n_pe, 256,
                                      park_capacity=q)
            t0 = time.perf_counter()
            out, dec = batch_lib.admit_stream_grow(
                state, batch, policy, n_pe=n_pe, backfill=mode)
            n_acc = int(np.asarray(dec.accepted).sum())
            wall = time.perf_counter() - t0
            run.accepted = n_acc
            run.parked = int(out.n_parked)
            return wall

        return run

    cases = [
        ("none", busy, "none", 0),
        ("easy", busy, "easy", park_capacity),
        ("conservative", busy, "conservative", park_capacity),
        ("none_idle", idle, "none", 0),
        ("easy_idle", idle, "easy", park_capacity),
    ]
    # one cold run each (compiles + growth), then *interleaved* warm
    # samples round-robin across the cases: the published numbers are
    # cost *ratios* of ~tens-of-ms walls, and interleaving makes the
    # per-case medians see the same machine state (drift cancels in
    # the ratio instead of landing on one side of it)
    runs = {label: make_run(jobs, mode, q)
            for label, jobs, mode, q in cases}
    colds = {label: fn() for label, fn in runs.items()}
    samples: Dict[str, List[float]] = {label: [] for label in runs}
    for _ in range(max(repeats, 1)):
        for label, fn in runs.items():
            samples[label].append(fn())
    rows: List[Dict] = []
    walls: Dict[str, float] = {}
    for label, jobs, mode, q in cases:
        run = runs[label]
        cold = colds[label]
        warm = median(samples[label])
        walls[label] = warm
        rows.append({
            "mode": label,
            "park_capacity": q,
            "n_requests": len(jobs),
            "accepted": run.accepted,
            "parked": run.parked,
            "cold_wall_s": round(cold, 4),
            "warm_wall_s": round(warm, 4),
            "warm_decisions_per_s": round(
                len(jobs) / max(warm, 1e-9), 1),
        })
    # cross-PR speedups, machine-normalised: the frozen warm dps are
    # scaled by this runner's FF host-loop yardstick over the same
    # era's committed host number (benchmarks._measure; PR 4 rows
    # were re-measured on the PR 5 runner, so they share its host)
    yard = host_yardstick()
    eras = (
        ("pr4", PR4_BACKFILL_DPS, PR4_BACKFILL_COST,
         PR5_ADMISSION_HOST),
        ("pr5", PR5_BACKFILL_DPS, PR5_BACKFILL_COST,
         PR5_ADMISSION_HOST),
        ("pr6", PR6_BACKFILL_DPS, PR6_BACKFILL_COST,
         PR6_ADMISSION_HOST),
        ("pr9", PR9_BACKFILL_DPS, PR9_BACKFILL_COST,
         PR9_ADMISSION_HOST),
    )
    for row in rows:
        base = "none_idle" if row["mode"].endswith("_idle") else "none"
        row["warm_cost_vs_plain"] = round(
            walls[row["mode"]] / max(walls[base], 1e-9), 2)
        for era, dps, cost, hosts in eras:
            if row["mode"] not in dps:
                continue
            m = yard / max(hosts["FF"], 1e-9)
            row[f"speedup_vs_{era}"] = round(
                row["warm_decisions_per_s"]
                / max(dps[row["mode"]] * m, 1e-9), 2)
            row[f"{era}_cost_vs_plain"] = cost[row["mode"]]
    by = {r["mode"]: r for r in rows}
    assert by["conservative"]["accepted"] == by["none"]["accepted"], \
        "conservative must be decision-identical to none"
    assert by["easy"]["accepted"] >= by["none"]["accepted"], \
        "EASY lost acceptance on the benchmark workload"
    assert by["easy_idle"]["parked"] == 0, \
        "the idle stream parked something: not an empty-queue scenario"
    assert by["easy_idle"]["accepted"] == by["none_idle"]["accepted"]
    if out_path:
        payload = {
            "bench": "backfill_throughput",
            "n_jobs": len(busy), "n_pe": n_pe,
            "park_capacity": park_capacity, "seed": seed,
            "capacity": capacity, "repeats": repeats,
            "note": ("one-shot admit_stream per backfill mode; warm "
                     f"is the median of {repeats} warmed runs; "
                     "conservative is decision-identical to none; "
                     "EASY trades per-step deferral-queue compute for "
                     "strictly higher acceptance; the *_idle rows pin "
                     "the cond-gating win (EASY with an empty queue "
                     "~= none cost, DESIGN.md §7)"),
            "rows": rows,
        }
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    return rows
