"""Tenancy overhead: gated admission step cost and metrics-poll latency.

The multi-tenant subsystem (DESIGN.md §10) rides inside the fused
admit step — quota gate before the search, fair-share ranking in the
queue sweeps, per-tenant accumulators after commit — so its cost shows
up as a *step-cost ratio* against the identical stream with
``tenants=None``.  Two claims are measured into
``BENCH_tenancy.json``:

* ``tenancy_on`` vs ``tenancy_off``: warm requests/sec of the same
  ring-chunked offer stream with and without a 4-tenant table.  The
  zero-tenant path must stay at the PR 7 cost (it traces the exact
  PR 7 graph: a ``None`` table contributes no pytree leaves), and the
  tenanted path should stay within a small constant factor.
* ``metrics_poll``: polls/sec of ``Session.metrics(tenant=...)`` on an
  idle session.  The snapshot is cached until the next dispatch, so
  idle polls perform **zero** device fetches — the row records the
  fetch count as measured through the ``service._device_fetch`` choke
  point, and the check gate pins it at 0.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional

from repro.api import ReservationService, ServiceConfig
from repro.core.types import Policy
from repro.sim import WorkloadParams, generate
from repro.tenancy import TenantSpec

_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_TENANCY_PATH = str(_ROOT / "BENCH_tenancy.json")

N_TENANTS = 4


def _jobs(n_jobs: int, n_pe: int, seed: int):
    jobs = sorted(
        [j for j in generate(WorkloadParams(
            n_jobs=n_jobs, n_pe=n_pe, seed=seed,
            u_low=2.0, u_med=4.0, u_hi=6.0)) if j.n_pe <= n_pe],
        key=lambda j: j.t_a)
    import dataclasses
    return [dataclasses.replace(j, tenant=i % N_TENANTS)
            for i, j in enumerate(jobs)]


def tenancy_throughput(n_jobs: int = 240, n_pe: int = 64,
                       chunk: int = 64, seed: int = 0,
                       repeats: int = 5,
                       out_path: Optional[str] = BENCH_TENANCY_PATH
                       ) -> List[Dict]:
    """Warm offer throughput with/without tenants + idle poll rate."""
    from benchmarks._measure import median, median_wall

    jobs = _jobs(n_jobs, n_pe, seed)

    def run_stream(tenants) -> float:
        sess = ReservationService(ServiceConfig(
            n_pe=n_pe, policy=Policy.PE_W, capacity=128,
            pending_capacity=256, chunk_size=chunk,
            ring_capacity=2 * chunk, tenants=tenants)).session()
        t0 = time.perf_counter()
        i = 0
        while i < len(jobs):
            sess.offer(jobs[i:i + chunk])
            i += chunk
        sess.metrics()          # decision + counter sync
        return time.perf_counter() - t0

    spec = TenantSpec(weights=(1.0,) * N_TENANTS)
    wall_off = median_wall(lambda: run_stream(None), repeats)
    wall_on = median_wall(lambda: run_stream(spec), repeats)

    # idle metrics polling on a drained multi-tenant session, with the
    # device-fetch choke point instrumented
    from repro.api import service as service_mod
    sess = ReservationService(ServiceConfig(
        n_pe=n_pe, policy=Policy.PE_W, capacity=128,
        pending_capacity=256, chunk_size=chunk,
        ring_capacity=2 * chunk, tenants=spec)).session()
    sess.offer(jobs)
    sess.metrics(tenant=0)      # warm the snapshot cache
    real = service_mod._device_fetch
    fetches = [0]

    def counting(tree):
        fetches[0] += 1
        return real(tree)

    service_mod._device_fetch = counting
    try:
        n_polls = 2000

        def poll() -> float:
            t0 = time.perf_counter()
            for k in range(n_polls):
                sess.metrics(tenant=k % N_TENANTS)
            return time.perf_counter() - t0

        poll_wall = median(poll() for _ in range(max(repeats, 1)))
        idle_fetches = fetches[0]
    finally:
        service_mod._device_fetch = real

    n = len(jobs)
    rows = [
        dict(variant="tenancy_off",
             warm_req_per_s=round(n / wall_off, 1),
             cost_vs_off=1.0),
        dict(variant="tenancy_on",
             warm_req_per_s=round(n / wall_on, 1),
             cost_vs_off=round(wall_on / max(wall_off, 1e-9), 3)),
        dict(variant="metrics_poll",
             polls_per_s=round(n_polls / max(poll_wall, 1e-9), 1),
             idle_device_fetches=idle_fetches),
    ]
    if out_path:
        with open(out_path, "w") as fh:
            json.dump({
                "description": "tenancy-on vs tenancy-off step cost "
                               "and idle metrics-poll latency",
                "n_jobs": n, "n_pe": n_pe, "chunk": chunk,
                "n_tenants": N_TENANTS, "rows": rows,
            }, fh, indent=2)
            fh.write("\n")
    return rows


if __name__ == "__main__":
    for row in tenancy_throughput():
        print(row)
