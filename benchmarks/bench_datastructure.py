"""Data-structure operation microbenchmarks (paper Section 4 claims).

Measures add/delete/find cost per operation for all three engines as
the number of live records grows — the empirical counterpart of the
paper's complexity analysis — plus the device engine's kernel-path scan
throughput (candidates x slots x PEs per second).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.scheduler import _make_engine
from repro.core.types import ARRequest, Policy


def _drive(engine: str, n_pe: int, n_jobs: int, seed: int = 0,
           **kwargs) -> Dict[str, float]:
    rng = np.random.default_rng(seed)
    s = _make_engine(n_pe, engine=engine, **kwargs)
    t_now = 0
    active: List = []
    t_find = t_add = t_del = 0.0
    n_find = n_add = n_del = 0
    max_records = 0
    for _ in range(n_jobs):
        t_now += int(rng.integers(0, 30))
        for job in [j for j in active if j[1] <= t_now]:
            t0 = time.perf_counter()
            s.delete_allocation(job[0], job[1], job[2])
            t_del += time.perf_counter() - t0
            n_del += 1
            active.remove(job)
        du = int(rng.integers(60, 3600))
        tr = t_now + int(rng.integers(0, 600))
        req = ARRequest(t_a=t_now, t_r=tr, t_du=du,
                        t_dl=tr + du + int(rng.integers(0, 3 * du)),
                        n_pe=int(rng.integers(1, n_pe // 2)))
        t0 = time.perf_counter()
        alloc = s.find_allocation(req, Policy.PE_W, t_now=t_now)
        t_find += time.perf_counter() - t0
        n_find += 1
        if alloc is not None:
            pes = (set(alloc.pe_ids) if engine == "list"
                   else list(alloc.pe_ids))
            t0 = time.perf_counter()
            s.add_allocation(alloc.t_s, alloc.t_e, pes)
            t_add += time.perf_counter() - t0
            n_add += 1
            active.append((alloc.t_s, alloc.t_e, pes))
        max_records = max(max_records, len(s.records()))
    return {
        "engine": engine,
        "n_pe": n_pe,
        "find_us": 1e6 * t_find / max(n_find, 1),
        "add_us": 1e6 * t_add / max(n_add, 1),
        "delete_us": 1e6 * t_del / max(n_del, 1),
        "max_records": max_records,
    }


def op_costs(n_jobs: int = 400) -> List[Dict]:
    rows = []
    for engine, kw in (("list", {}), ("host", {}),
                       ("device", {"capacity": 256}),
                       ("device-kernel", {"capacity": 256,
                                          "use_kernel": True})):
        eng = "device" if engine.startswith("device") else engine
        rows.append(_drive(eng, 1024, n_jobs, **kw))
        rows[-1]["engine"] = engine
    return rows


def scaling_with_pe_count(n_jobs: int = 250) -> List[Dict]:
    """Host engine op cost as the machine grows 256 -> 4096 PEs."""
    return [_drive("host", n, n_jobs) for n in (256, 1024, 4096)]
