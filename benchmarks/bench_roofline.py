"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads ``artifacts/dryrun/*__single.json`` and emits one row per
(arch x shape): the three roofline terms, the dominant bottleneck, the
6*N*D model FLOPs and the useful-compute ratio.  Rerun
``python -m repro.launch.dryrun`` to refresh the artifacts.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

ART = Path("artifacts/dryrun")
ART_OPT = Path("artifacts/dryrun_opt")


def roofline_rows(mesh: str = "single", art: Path = None) -> List[Dict]:
    rows = []
    art = ART if art is None else art
    if not art.exists():
        return []
    for path in sorted(art.glob(f"*__{mesh}.json")):
        rec = json.loads(path.read_text())
        if rec["status"] != "OK":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": rec["status"]})
            continue
        r = rec["roofline"]
        a = rec["analytic"]
        rows.append({
            "arch": rec["arch"],
            "shape": rec["shape"],
            "status": "OK",
            "compute_s": f"{r['compute_s']:.3e}",
            "memory_s": f"{r['memory_s']:.3e}",
            "collective_s": f"{r['collective_s']:.3e}",
            "dominant": r["dominant"],
            "model_flops": f"{a['model_flops']:.3e}",
            "useful_ratio": round(r["useful_ratio"], 3),
            "fits_hbm": rec["memory"]["model_fits_16g_hbm"],
            "compile_s": rec["compile_s"],
        })
    return rows
