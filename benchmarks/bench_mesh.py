"""Mesh-sharded dispatch + pipelined donated streaming (DESIGN.md §8).

Two sections into ``BENCH_mesh.json``:

* ``sharded_grid`` — an 8x Section-6 experiment matrix (7 policies x
  3 loads x 24 seeds = 504 lanes, vs the 63-cell reference grid) as
  ONE vmapped dispatch, ``placement="auto"`` (lanes sharded over every
  local device) against ``placement="single"`` (the pre-mesh path).
  Decisions are asserted bit-identical; the published number is
  cells/sec per variant plus the steady-state jit-cache delta (zero
  recompiles after warmup).  On a single-device host the two variants
  measure the same machine — the honest expectation is ratio ~1.0, and
  the regression gate is on the *committed* ratio, not a hoped-for Nx.

* ``offer_overlap`` — one streaming session admitting the same
  arrival stream through the ring in fixed chunks, the pipelined
  donated path (host stages chunk k+1 while the device admits chunk
  k, one deferred overflow read) against the eager per-chunk path
  (``donate=False``: one host round-trip per chunk).  Decisions are
  asserted identical; rows carry warm requests/sec, the steady-state
  recompile count, and the growth count (zero = allocation-free
  steady state).
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional

from benchmarks._measure import median_wall
from repro.api import ReservationService, ServiceConfig
from repro.core import batch as batch_lib
from repro.core import ensemble as ens_lib
from repro.core.types import ALL_POLICIES, Policy
from repro.launch.mesh import data_shards, resolve_placement
from repro.sim import GridSpec, WorkloadParams, generate, simulate_grid

_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_MESH_PATH = str(_ROOT / "BENCH_mesh.json")

# the 63-cell grid of bench_policies.sweep_throughput is the reference
# size; 24 seeds x 3 loads x 7 policies = 504 lanes = 8x that grid,
# divisible by 1..8-way meshes so every forced-device count shards
_REFERENCE_CELLS = 63


def _write_section(section: str, payload: Dict,
                   out_path: Optional[str]) -> None:
    """Read-modify-write one section of the shared BENCH_mesh.json."""
    if not out_path:
        return
    path = pathlib.Path(out_path)
    doc = {"bench": "mesh"}
    if path.exists():
        with open(path) as fh:
            doc = json.load(fh)
    doc[section] = payload
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")


def sharded_grid(n_seeds: int = 24, n_jobs: int = 120, n_pe: int = 64,
                 capacity: int = 32, repeats: int = 3,
                 out_path: Optional[str] = BENCH_MESH_PATH
                 ) -> List[Dict]:
    """Cells/sec of the 504-lane grid, sharded vs single placement.

    The matrix is 8x the reference sweep grid and still ONE dispatch:
    every workload is generated once, shared across policies, and all
    504 lanes admit in lockstep.  ``sharded_auto`` places the lane
    axis over every local device (``resolve_placement("auto")``);
    ``single_device`` is the unsharded baseline.  The first
    ``record_decisions`` run per variant doubles as the warmup and
    feeds the bit-identity assert; timed runs then count jit-cache
    entries of the donated ensemble scan — the steady state must not
    recompile.
    """
    spec = GridSpec(
        policies=ALL_POLICIES, arrival_factors=(1.0, 1.5, 2.0),
        seeds=tuple(range(n_seeds)), flex_factors=(3.0,),
        base=WorkloadParams(u_low=2.0, u_med=4.0, u_hi=6.0),
        n_pe=n_pe, n_jobs=n_jobs)
    n_cells = spec.n_cells
    mesh = resolve_placement("auto", n_cells)
    shards = data_shards(mesh) if mesh is not None else 1

    decisions: Dict[str, list] = {}
    rows: List[Dict] = []
    walls: Dict[str, float] = {}
    for variant, placement in (("sharded_auto", "auto"),
                               ("single_device", "single")):
        cache0 = ens_lib.admit_stream_ensemble_donated._cache_size()
        # warmup run records decisions for the bit-identity assert
        decisions[variant] = simulate_grid(
            spec, capacity=capacity, placement=placement,
            record_decisions=True).decisions

        def run(p=placement) -> float:
            return simulate_grid(
                spec, capacity=capacity, placement=p).wall_seconds

        run()                       # second warmup: growth fixed point
        steady0 = ens_lib.admit_stream_ensemble_donated._cache_size()
        wall = median_wall(run, repeats)
        steady_recompiles = (
            ens_lib.admit_stream_ensemble_donated._cache_size()
            - steady0)
        walls[variant] = wall
        rows.append({
            "variant": variant,
            "n_cells": n_cells,
            "grid_x_vs_reference": round(n_cells / _REFERENCE_CELLS, 1),
            "data_shards": shards if variant == "sharded_auto" else 1,
            "wall_s": round(wall, 4),
            "cells_per_s": round(n_cells / max(wall, 1e-9), 2),
            "warmup_compiles": steady0 - cache0,
            "steady_recompiles": steady_recompiles,
        })
    assert decisions["sharded_auto"] == decisions["single_device"], \
        "sharded grid decisions diverge from the single-device path"
    for row in rows:
        row["speedup_vs_single"] = round(
            walls["single_device"] / max(walls[row["variant"]], 1e-9),
            2)
        row["decisions_bit_identical"] = True
    _write_section("sharded_grid", {
        "grid": {"policies": len(spec.policies),
                 "arrival_factors": list(spec.arrival_factors),
                 "n_seeds": n_seeds, "n_jobs": n_jobs, "n_pe": n_pe,
                 "n_cells": n_cells,
                 "reference_cells": _REFERENCE_CELLS},
        "capacity": capacity, "repeats": repeats,
        "local_devices": shards,
        "note": (f"{n_cells}-lane Section-6 grid as one dispatch, "
                 "warmed-up median walls; decisions asserted "
                 "bit-identical sharded vs single; on a 1-device "
                 "host speedup_vs_single ~1.0 is the honest "
                 "expectation (the gate is vs the committed ratio); "
                 "steady_recompiles must be 0"),
        "rows": rows,
    }, out_path)
    return rows


def offer_overlap(n_jobs: int = 240, n_pe: int = 64, chunk: int = 32,
                  seed: int = 0, capacity: int = 256,
                  repeats: int = 5,
                  out_path: Optional[str] = BENCH_MESH_PATH
                  ) -> List[Dict]:
    """Requests/sec of pipelined-donated vs eager chunked streaming.

    One stream session, the whole arrival stream offered through the
    ring in fixed ``chunk``-sized dispatches.  ``pipelined`` is the
    donated double-buffer protocol (stage chunk k+1 while the device
    admits chunk k; one deferred overflow read at drain);
    ``eager`` is ``donate=False`` — the pre-mesh path with one
    blocking decision sync per chunk.  ``capacity`` is sized so the
    steady state never grows: rows assert 0 growths and 0 recompiles
    after warmup (the allocation-free claim, DESIGN.md §8).
    """
    jobs = sorted(
        [j for j in generate(WorkloadParams(
            n_jobs=n_jobs, n_pe=n_pe, seed=seed,
            u_low=2.0, u_med=4.0, u_hi=6.0)) if j.n_pe <= n_pe],
        key=lambda j: j.t_a)
    policy = Policy.PE_W

    def make_run(donate: bool):
        def run() -> float:
            sess = ReservationService(ServiceConfig(
                n_pe=n_pe, policy=policy, capacity=capacity,
                pending_capacity=2 * capacity, chunk_size=chunk,
                ring_capacity=2 * chunk, donate=donate)).session()
            t0 = time.perf_counter()
            res = sess.offer(jobs)
            accepted = res.n_accepted      # syncs the device
            wall = time.perf_counter() - t0
            m = sess.metrics()
            run.accepted = accepted
            run.growths = m["growths"]
            run.chunks = m["chunks"]
            return wall

        return run

    rows: List[Dict] = []
    walls: Dict[str, float] = {}
    for variant, donate in (("pipelined", True), ("eager", False)):
        run = make_run(donate)
        run()                                    # compile + warm
        steady0 = batch_lib.admit_stream_donated._cache_size()
        wall = median_wall(run, repeats)
        steady_recompiles = (
            batch_lib.admit_stream_donated._cache_size() - steady0)
        walls[variant] = wall
        rows.append({
            "variant": variant,
            "n_requests": len(jobs),
            "chunk": chunk,
            "n_chunks": run.chunks,
            "accepted": run.accepted,
            "warm_wall_s": round(wall, 4),
            "warm_req_per_s": round(len(jobs) / max(wall, 1e-9), 1),
            "steady_recompiles": steady_recompiles,
            "steady_growths": run.growths,
        })
    by = {r["variant"]: r for r in rows}
    assert by["pipelined"]["accepted"] == by["eager"]["accepted"], \
        "pipelined offer diverged from the eager per-chunk path"
    assert by["pipelined"]["steady_growths"] == 0, \
        "steady-state pipelined run re-allocated (grew) state"
    assert by["pipelined"]["steady_recompiles"] == 0, \
        "steady-state pipelined run recompiled the donated scan"
    for row in rows:
        row["overlap_speedup_vs_eager"] = round(
            walls["eager"] / max(walls[row["variant"]], 1e-9), 2)
    _write_section("offer_overlap", {
        "n_jobs": len(jobs), "n_pe": n_pe, "chunk": chunk,
        "seed": seed, "capacity": capacity, "repeats": repeats,
        "note": ("one session, whole stream through the ring in "
                 f"{chunk}-request chunks; pipelined = donated "
                 "double-buffer (deferred overflow read), eager = "
                 "donate=False per-chunk sync; decisions identical; "
                 "steady state asserts 0 growths / 0 recompiles"),
        "rows": rows,
    }, out_path)
    return rows
