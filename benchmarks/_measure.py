"""Shared benchmark measurement helpers + the frozen PR baselines.

Every BENCH_*.json row carries ``speedup_vs_pr5`` (and the older
``speedup_vs_pr4``) against the numbers the corresponding PR's tree
committed — copied verbatim below, so re-running the benchmarks never
chains the comparison onto itself.  Wall times are warmed-up medians:
a single steady-state run (the pre-PR 5 protocol) was noisy enough on
shared CPU runners to move published ratios by tens of percent.

Machine normalisation: the runners that measure successive PRs are
not the same hardware (core counts alone moved absolute walls by 3x
between trees), so cross-PR speedups divide out a *machine factor* —
the geomean of the freshly measured host-loop throughputs over the
geomean of the same host numbers the reference PR committed
(:func:`machine_factor`).  The host numpy engine is the stable
yardstick both trees ran unchanged; the geomean (not per-policy
ratios) dampens the per-policy host noise that otherwise leaks into
the comparison.
"""
from __future__ import annotations

import math
from typing import Callable, Iterable, List, Mapping


def geomean(vals: Iterable[float]) -> float:
    s = [max(float(v), 1e-9) for v in vals]
    return math.exp(sum(math.log(v) for v in s) / max(len(s), 1))


def machine_factor(fresh_hosts: Mapping[str, float],
                   frozen_hosts: Mapping[str, float]) -> float:
    """this-machine speed vs the reference PR's runner (host geomean).

    Keys present in both mappings are compared; the result multiplies
    the frozen device baselines before any cross-PR speedup so the
    ratio prices the *tree*, not the runner.
    """
    common = sorted(set(fresh_hosts) & set(frozen_hosts))
    if not common:
        return 1.0
    return geomean(fresh_hosts[k] for k in common) / geomean(
        frozen_hosts[k] for k in common)


def host_yardstick(n_jobs: int = 240, repeats: int = 3) -> float:
    """FF host-loop admissions/sec on the standard admission workload.

    A cheap this-machine speed probe for benches that have no host
    variant of their own (backfill, service): divide by the same
    era's ``PRx_ADMISSION_HOST["FF"]`` to get that bench's machine
    factor.
    """
    from repro.core.types import Policy
    from repro.sim import WorkloadParams, generate, simulate

    jobs = [j for j in generate(WorkloadParams(
        n_jobs=n_jobs, n_pe=64, seed=0,
        u_low=2.0, u_med=4.0, u_hi=6.0)) if j.n_pe <= 64]
    wall = median_wall(
        lambda: simulate(jobs, 64, Policy.FF,
                         engine="host").wall_seconds, repeats)
    return len(jobs) / max(wall, 1e-9)


def median(vals: Iterable[float]) -> float:
    """Upper median (odd counts: the true median) — the one
    measurement protocol for every bench; repeats are odd in-repo."""
    s: List[float] = sorted(vals)
    return s[len(s) // 2]


def median_wall(fn: Callable[[], float], repeats: int = 5) -> float:
    """Median wall of ``repeats`` runs after one warmup run.

    The warmup run populates jit caches *and* runs the grow-once
    overflow protocol to its fixed point, so the measured runs see the
    steady-state shapes.  ``fn`` returns its own wall seconds.
    """
    fn()
    return median(fn() for _ in range(max(repeats, 1)))


# --------------------------------------------------------------------------
# PR 4 baselines (the BENCH_*.json rows committed by PR 4)
# --------------------------------------------------------------------------

# admissions/sec of the scanned device path (BENCH_admission.json)
PR4_ADMISSION_STREAM = {
    "FF": 1367.1, "PE_B": 2648.9, "PE_W": 1341.4, "Du_B": 2009.6,
    "Du_W": 2015.4, "PEDu_B": 1902.8, "PEDu_W": 1368.0,
}

# Section-6 grid cells/sec (BENCH_sweep.json)
PR4_SWEEP_CELLS = {
    "host_loop": 44.16, "device_scan": 18.6, "vmapped_grid": 25.0,
}

# warm decisions/sec per backfill mode (BENCH_backfill.json)
PR4_BACKFILL_DPS = {
    "none": 8890.6, "easy": 1001.4, "conservative": 5833.2,
}
# warm step-cost ratios vs the plain (mode "none") scan
PR4_BACKFILL_COST = {"none": 1.0, "easy": 8.88, "conservative": 1.52}

# warm requests/sec of the streaming variants (BENCH_service.json)
PR4_SERVICE_WARM = {"rescan_per_group": 1829.5, "ring_chunked": 2116.1}


def speedup_vs_pr4(value: float, baseline: float) -> float:
    return round(value / max(baseline, 1e-9), 2)


# --------------------------------------------------------------------------
# PR 5 baselines (the BENCH_*.json rows committed by PR 5)
# --------------------------------------------------------------------------

# admissions/sec of the scanned device path.  RECALIBRATED at PR 10:
# the rows PR 5 committed were one-shot samples whose per-policy noise
# (PE_B 17053 on a run whose other policies measured 10-13k) made the
# per-row trajectory floor unmeetable by any honest re-measurement, so
# the PR 5 *code* (commit 1d7f046) was checked out and re-measured on
# the PR 10 runner with the current round-robin protocol — medians of
# 7 policy-major rounds, 3x stream oversampling, same workload
# (n_jobs=240, n_pe=64, seed 0, capacity 32).
PR5_ADMISSION_STREAM = {
    "FF": 9738.7, "PE_B": 9406.1, "PE_W": 9714.5, "Du_B": 10359.6,
    "Du_W": 9860.1, "PEDu_B": 11874.4, "PEDu_W": 10319.4,
}

# the host-loop yardstick paired with the recalibrated stream rows:
# the *current* host engine measured on the recalibration runner in
# the same session, so the speedup_vs_pr5 machine factor is ~1 there
# and scales by host speed on any other runner.  (Pairing the frozen
# PR 5 host engine instead would fold host-engine improvements into
# the machine factor and re-bias every row.)
PR5_STREAM_YARDSTICK_HOST = {
    "FF": 4304.3, "PE_B": 4447.6, "PE_W": 4129.6, "Du_B": 4068.0,
    "Du_W": 4799.0, "PEDu_B": 4353.4, "PEDu_W": 4152.8,
}

# host-loop admissions/sec the PR 5 tree committed — the yardstick for
# the frozen rows still tied to the original PR 5 runner: the PR 4
# stream rows (re-measured there; PR 4 published no host rows) and the
# PR 5 backfill/service rows below
PR5_ADMISSION_HOST = {
    "FF": 4246.7, "PE_B": 1956.5, "PE_W": 5904.7, "Du_B": 5100.4,
    "Du_W": 5798.9, "PEDu_B": 7409.9, "PEDu_W": 4402.4,
}

# Section-6 grid cells/sec (BENCH_sweep.json)
PR5_SWEEP_CELLS = {
    "host_loop": 45.75, "device_scan": 124.44, "vmapped_grid": 72.3,
}

# warm decisions/sec per backfill mode (BENCH_backfill.json)
PR5_BACKFILL_DPS = {
    "none": 9507.3, "easy": 1956.7, "conservative": 8565.5,
}
# warm step-cost ratios vs the plain (mode "none") scan
PR5_BACKFILL_COST = {"none": 1.0, "easy": 4.86, "conservative": 1.11}

# warm requests/sec of the streaming variants (BENCH_service.json)
PR5_SERVICE_WARM = {"rescan_per_group": 2884.7, "ring_chunked": 1953.0}


def speedup_vs_pr5(value: float, baseline: float) -> float:
    return round(value / max(baseline, 1e-9), 2)


# --------------------------------------------------------------------------
# PR 6 baselines (the BENCH_*.json rows committed by PR 6)
# --------------------------------------------------------------------------

# admissions/sec of the scanned device path (BENCH_admission.json)
PR6_ADMISSION_STREAM = {
    "FF": 14024.7, "PE_B": 14132.1, "PE_W": 11237.8, "Du_B": 14368.1,
    "Du_W": 14880.9, "PEDu_B": 13494.4, "PEDu_W": 13528.4,
}

# host-loop admissions/sec the same PR 6 tree committed
PR6_ADMISSION_HOST = {
    "FF": 5087.4, "PE_B": 4320.7, "PE_W": 3928.9, "Du_B": 4666.8,
    "Du_W": 5443.7, "PEDu_B": 4337.3, "PEDu_W": 4220.3,
}

# Section-6 grid cells/sec (BENCH_sweep.json)
PR6_SWEEP_CELLS = {
    "host_loop": 38.52, "device_scan": 107.34, "vmapped_grid": 77.16,
}

# warm decisions/sec per backfill mode (BENCH_backfill.json)
PR6_BACKFILL_DPS = {
    "none": 14625.6, "easy": 2541.5, "conservative": 13725.0,
    "none_idle": 7189.1, "easy_idle": 6892.1,
}
# warm step-cost ratios vs the plain (mode "none") scan
PR6_BACKFILL_COST = {
    "none": 1.0, "easy": 5.75, "conservative": 1.07,
    "none_idle": 1.0, "easy_idle": 1.04,
}

# warm requests/sec of the streaming variants (BENCH_service.json)
PR6_SERVICE_WARM = {"rescan_per_group": 3965.5, "ring_chunked": 2370.9}


def speedup_vs_pr6(value: float, baseline: float) -> float:
    return round(value / max(baseline, 1e-9), 2)


# --------------------------------------------------------------------------
# PR 9 baselines (the BENCH_*.json rows committed going into the
# hierarchical-index PR — the last pre-index tree)
# --------------------------------------------------------------------------

# admissions/sec of the scanned device path (BENCH_admission.json)
PR9_ADMISSION_STREAM = {
    "FF": 14566.7, "PE_B": 13444.6, "PE_W": 14266.3, "Du_B": 14082.0,
    "Du_W": 15161.9, "PEDu_B": 15523.5, "PEDu_W": 12580.9,
}

# host-loop admissions/sec the same tree committed
PR9_ADMISSION_HOST = {
    "FF": 4689.2, "PE_B": 4929.3, "PE_W": 3956.7, "Du_B": 4724.5,
    "Du_W": 5323.4, "PEDu_B": 5255.6, "PEDu_W": 5090.4,
}

# Section-6 grid cells/sec (BENCH_sweep.json)
PR9_SWEEP_CELLS = {
    "host_loop": 41.79, "device_scan": 113.82, "vmapped_grid": 87.04,
}

# warm decisions/sec per backfill mode (BENCH_backfill.json)
PR9_BACKFILL_DPS = {
    "none": 14308.6, "easy": 2538.3, "conservative": 13508.9,
    "none_idle": 7148.1, "easy_idle": 6984.5,
}
# warm step-cost ratios vs the plain (mode "none") scan
PR9_BACKFILL_COST = {
    "none": 1.0, "easy": 5.64, "conservative": 1.06,
    "none_idle": 1.0, "easy_idle": 1.02,
}

# warm requests/sec of the streaming variants (BENCH_service.json)
PR9_SERVICE_WARM = {"rescan_per_group": 3545.5, "ring_chunked": 2301.2}


def speedup_vs_pr9(value: float, baseline: float) -> float:
    return round(value / max(baseline, 1e-9), 2)
