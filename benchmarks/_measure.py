"""Shared benchmark measurement helpers + the frozen PR baselines.

Every BENCH_*.json row carries ``speedup_vs_pr5`` (and the older
``speedup_vs_pr4``) against the numbers the corresponding PR's tree
committed — copied verbatim below, so re-running the benchmarks never
chains the comparison onto itself.  Wall times are warmed-up medians:
a single steady-state run (the pre-PR 5 protocol) was noisy enough on
shared CPU runners to move published ratios by tens of percent.
"""
from __future__ import annotations

from typing import Callable, Iterable, List


def median(vals: Iterable[float]) -> float:
    """Upper median (odd counts: the true median) — the one
    measurement protocol for every bench; repeats are odd in-repo."""
    s: List[float] = sorted(vals)
    return s[len(s) // 2]


def median_wall(fn: Callable[[], float], repeats: int = 5) -> float:
    """Median wall of ``repeats`` runs after one warmup run.

    The warmup run populates jit caches *and* runs the grow-once
    overflow protocol to its fixed point, so the measured runs see the
    steady-state shapes.  ``fn`` returns its own wall seconds.
    """
    fn()
    return median(fn() for _ in range(max(repeats, 1)))


# --------------------------------------------------------------------------
# PR 4 baselines (the BENCH_*.json rows committed by PR 4)
# --------------------------------------------------------------------------

# admissions/sec of the scanned device path (BENCH_admission.json)
PR4_ADMISSION_STREAM = {
    "FF": 1367.1, "PE_B": 2648.9, "PE_W": 1341.4, "Du_B": 2009.6,
    "Du_W": 2015.4, "PEDu_B": 1902.8, "PEDu_W": 1368.0,
}

# Section-6 grid cells/sec (BENCH_sweep.json)
PR4_SWEEP_CELLS = {
    "host_loop": 44.16, "device_scan": 18.6, "vmapped_grid": 25.0,
}

# warm decisions/sec per backfill mode (BENCH_backfill.json)
PR4_BACKFILL_DPS = {
    "none": 8890.6, "easy": 1001.4, "conservative": 5833.2,
}
# warm step-cost ratios vs the plain (mode "none") scan
PR4_BACKFILL_COST = {"none": 1.0, "easy": 8.88, "conservative": 1.52}

# warm requests/sec of the streaming variants (BENCH_service.json)
PR4_SERVICE_WARM = {"rescan_per_group": 1829.5, "ring_chunked": 2116.1}


def speedup_vs_pr4(value: float, baseline: float) -> float:
    return round(value / max(baseline, 1e-9), 2)


# --------------------------------------------------------------------------
# PR 5 baselines (the BENCH_*.json rows committed by PR 5)
# --------------------------------------------------------------------------

# admissions/sec of the scanned device path (BENCH_admission.json)
PR5_ADMISSION_STREAM = {
    "FF": 13437.8, "PE_B": 17053.2, "PE_W": 12553.4, "Du_B": 13449.9,
    "Du_W": 16026.1, "PEDu_B": 10037.9, "PEDu_W": 15356.7,
}

# Section-6 grid cells/sec (BENCH_sweep.json)
PR5_SWEEP_CELLS = {
    "host_loop": 45.75, "device_scan": 124.44, "vmapped_grid": 72.3,
}

# warm decisions/sec per backfill mode (BENCH_backfill.json)
PR5_BACKFILL_DPS = {
    "none": 9507.3, "easy": 1956.7, "conservative": 8565.5,
}
# warm step-cost ratios vs the plain (mode "none") scan
PR5_BACKFILL_COST = {"none": 1.0, "easy": 4.86, "conservative": 1.11}

# warm requests/sec of the streaming variants (BENCH_service.json)
PR5_SERVICE_WARM = {"rescan_per_group": 2884.7, "ring_chunked": 1953.0}


def speedup_vs_pr5(value: float, baseline: float) -> float:
    return round(value / max(baseline, 1e-9), 2)


# --------------------------------------------------------------------------
# PR 6 baselines (the BENCH_*.json rows committed by PR 6)
# --------------------------------------------------------------------------

# admissions/sec of the scanned device path (BENCH_admission.json)
PR6_ADMISSION_STREAM = {
    "FF": 14024.7, "PE_B": 14132.1, "PE_W": 11237.8, "Du_B": 14368.1,
    "Du_W": 14880.9, "PEDu_B": 13494.4, "PEDu_W": 13528.4,
}

# Section-6 grid cells/sec (BENCH_sweep.json)
PR6_SWEEP_CELLS = {
    "host_loop": 38.52, "device_scan": 107.34, "vmapped_grid": 77.16,
}

# warm decisions/sec per backfill mode (BENCH_backfill.json)
PR6_BACKFILL_DPS = {
    "none": 14625.6, "easy": 2541.5, "conservative": 13725.0,
    "none_idle": 7189.1, "easy_idle": 6892.1,
}
# warm step-cost ratios vs the plain (mode "none") scan
PR6_BACKFILL_COST = {
    "none": 1.0, "easy": 5.75, "conservative": 1.07,
    "none_idle": 1.0, "easy_idle": 1.04,
}

# warm requests/sec of the streaming variants (BENCH_service.json)
PR6_SERVICE_WARM = {"rescan_per_group": 3965.5, "ring_chunked": 2370.9}


def speedup_vs_pr6(value: float, baseline: float) -> float:
    return round(value / max(baseline, 1e-9), 2)
