"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
the dry-run artifacts.  Usage:

    PYTHONPATH=src python -m benchmarks.gen_experiments > /tmp/tables.md

Refresh the artifacts first with ``python -m repro.launch.dryrun``;
the ``experiments_tables`` section of :mod:`benchmarks.run` reports
each table's row count (or that the artifacts are missing) without
dumping the markdown into the CSV stream.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

ART = Path("artifacts/dryrun")


def _gb(x) -> str:
    return f"{x/2**30:.2f}"


def dryrun_table() -> str:
    lines = [
        "| arch | shape | mesh | status | compile_s | HLO colls (GB) |"
        " args/dev (GiB) | model mem/dev (GiB) | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for p in sorted(ART.glob("*.json")):
        r = json.loads(p.read_text())
        if r["status"] == "SKIPPED":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIPPED"
                f" ({r['reason'][:42]}...) | | | | |")
            continue
        if r["status"] != "OK":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                         f" FAILED | | | | |")
            continue
        m = r["memory"]
        colls = r["hlo_raw"]["collectives"].get("total", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
            f"{r['compile_s']} | {colls/1e9:.2f} | "
            f"{_gb(m['model_args_bytes'])} | "
            f"{_gb(m['model_per_device_total'])} | "
            f"{'yes' if m['model_fits_16g_hbm'] else 'NO'} |")
    return "\n".join(lines)


def roofline_table(mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | "
        "dominant | MODEL_FLOPS (6ND) | useful ratio | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        ("compute", "train"): "raise MFU: fuse attn, better remat split",
        ("compute", "prefill"): "blockwise attn skips causal half",
        ("memory", "decode"): "int8 KV cache / wider batch per chip",
        ("collective", "prefill"): "shard heads not ctx; overlap a2a",
        ("collective", "train"): "quantized dispatch + comm overlap",
        ("memory", "train"): "recompute more, save less",
    }
    for p in sorted(ART.glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r["status"] != "OK":
            continue
        t = r["roofline"]
        a = r["analytic"]
        lever = levers.get((t["dominant"], r["kind"]), "-")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"**{t['dominant']}** | {a['model_flops']:.3e} | "
            f"{t['useful_ratio']:.3f} | {lever} |")
    return "\n".join(lines)


def tables() -> List[Dict]:
    """Row-per-table summary for the benchmark harness.

    The markdown itself goes to stdout via ``__main__``; the harness
    section only reports what would be generated, so a tree without
    dry-run artifacts still lists cleanly.
    """
    if not ART.is_dir() or not any(ART.glob("*.json")):
        return [{"table": "dryrun", "status": "no artifacts "
                 "(run python -m repro.launch.dryrun)", "data_rows": 0}]
    specs = (("dryrun", dryrun_table()),
             ("roofline_single", roofline_table("single")),
             ("roofline_multi", roofline_table("multi")))
    return [{"table": name, "status": "ok",
             "data_rows": max(len(md.splitlines()) - 2, 0)}
            for name, md in specs]


if __name__ == "__main__":
    print("### Dry-run (all cells x both meshes)\n")
    print(dryrun_table())
    print("\n### Roofline baseline (single-pod 16x16, 256 chips)\n")
    print(roofline_table("single"))
    print("\n### Roofline (multi-pod 2x16x16, 512 chips)\n")
    print(roofline_table("multi"))
